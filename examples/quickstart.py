"""Quickstart: the LERC core in 60 lines — the paper's Fig. 1 example,
then a policy comparison on the paper's multi-tenant zip workload.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (BlockMeta, CacheManager, DagState, JobDAG, TaskSpec,
                        make_policy)
from repro.sim import ClusterSim, HardwareModel, multi_tenant_zip

# --- Paper Fig. 1: blocks a,b,c cached; d on disk; e arrives ---------------
dag = JobDAG()
for name in "abcde":
    dag.add_source(name, 0, size=1)
dag.add_block(BlockMeta("x", 2, "x", 0))
dag.add_block(BlockMeta("y", 2, "y", 0))
dag.add_task(TaskSpec("task1", ("a[0]", "b[0]"), "x", job="j"))
dag.add_task(TaskSpec("task2", ("c[0]", "d[0]"), "y", job="j"))

for policy in ("lru", "lrc", "lerc"):
    state = DagState(dag)
    mgr = CacheManager(capacity=3, policy=make_policy(policy), state=state)
    for b in ("a[0]", "b[0]", "c[0]"):
        mgr.insert(b, 1)
    mgr.disk.put("d[0]", 1)
    state.on_materialized("d[0]", into_cache=False)
    victims = mgr.insert("e[0]", 1)
    verdict = "RIGHT" if victims == ["c[0]"] else "wrong"
    print(f"{policy:5s} evicts {victims[0]:5s} ({verdict}: caching c "
          f"without d speeds up nothing)")

# --- Paper §IV in one sweep ------------------------------------------------
print("\nmulti-tenant zip (4 jobs x 40 blocks), cache 2 GB:")
for policy in ("lru", "lrc", "lerc"):
    hw = HardwareModel(cache_bytes=int(2.0 * 2 ** 30) // 20, disk_bw=25e6)
    sim = ClusterSim(20, hw, policy=policy)
    for jdag, _ in multi_tenant_zip(n_jobs=4, n_blocks=40, n_workers=20):
        sim.submit(jdag)
    sim.run(stages={0})
    res = sim.run(stages={1})
    m = res.metrics
    print(f"  {policy:5s} makespan {res.makespan:7.2f}s   "
          f"hit {m.hit_ratio:5.1%}   effective-hit {m.effective_hit_ratio:5.1%}")

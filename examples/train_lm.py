"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
CPU, fed by the LERC-managed data pipeline (tokens and targets arrive as
ZIPPED block pairs — the paper's peer groups — under cache pressure with
real disk spill), with async checkpointing and deterministic resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro import configs
from repro.data import Executor, Pipeline
from repro.models.common import ModelConfig
from repro.sharding import local_context
from repro.train import (AsyncCheckpointer, OptConfig, TrainConfig,
                         build_train_step, latest, load, make_train_state)


def lm_100m() -> ModelConfig:
    """~100M params, qwen2 family."""
    return ModelConfig(
        arch="qwen2_100m", family="dense",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab=32_000, qkv_bias=True, act="swiglu",
        tie_embeddings=True)


def build_lerc_pipeline(cfg, n_blocks, global_batch, seq_len, spill_dir,
                        cache_blocks=48, policy="lerc"):
    """Token blocks and label blocks are separate datasets (as if produced
    by different preprocessing jobs); each training batch zips one block of
    each — a peer group per step. The corpus is a fixed set of blocks
    cycled epoch-wise, so the model can memorize (loss decreases) and the
    cache sees repeated accesses."""
    rng = np.random.default_rng(0)
    tok_blocks = [rng.integers(0, cfg.vocab,
                               (global_batch, seq_len)).astype(np.int32)
                  for _ in range(n_blocks)]
    # labels: next-token shift of an underlying stream; here a paired block
    lab_blocks = [np.roll(tb, -1, axis=1) for tb in tok_blocks]
    pipe = Pipeline("train")
    rt = pipe.source(tok_blocks, "tokens")
    rl = pipe.source(lab_blocks, "labels")
    rz = pipe.zip_([rt, rl],
                   lambda t, l: np.stack([t, l]), "batches")
    ex = Executor(pipe, cache_bytes=cache_blocks * tok_blocks[0].nbytes,
                  policy=policy, spill_dir=spill_dir)
    ex.load_sources(rt)
    ex.load_sources(rl)
    return ex, rz


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--policy", default="lerc")
    args = ap.parse_args()

    cfg = lm_100m()
    from repro.models import model_spec, param_count
    print(f"model: {param_count(model_spec(cfg))/1e6:.1f}M params")

    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=20,
                                   total_steps=args.steps))
    state = make_train_state(cfg, tc, jax.random.key(0))
    step_fn = jax.jit(build_train_step(cfg, tc, local_context()),
                      donate_argnums=(0,))

    tmp = tempfile.mkdtemp(prefix="train_lm_")
    n_blocks = min(args.steps, 16)                  # cycled epoch-wise
    ex, rz = build_lerc_pipeline(cfg, n_blocks, args.global_batch,
                                 args.seq_len, os.path.join(tmp, "spill"),
                                 policy=args.policy)
    ckpt = AsyncCheckpointer(os.path.join(tmp, "ckpt"))

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        pair = ex.get(rz, step % n_blocks)          # LERC-cached peer pair
        batch = {"tokens": pair[0], "targets": pair[1]}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"({time.time()-t0:.0f}s)", flush=True)
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"state": state})
    ckpt.wait()

    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(must decrease on random data by memorization)")
    print("pipeline cache metrics:", ex.metrics.as_dict())
    print("pipeline io:", ex.stats)
    assert losses[-1] < losses[0], "training must reduce loss"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serve a small model with batched requests through the continuous-
batching engine; compare prefix-cache eviction policies under a constrained
KV budget (the paper's LERC vs LRU/LRC, on the serving side).

    PYTHONPATH=src python examples/serve_prefix_cache.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import init_params, model_spec
from repro.serve import PrefixStore, ServeEngine


def workload(vocab, rng, n_requests=24, n_families=6, prefix_len=32):
    fam_p = 1.0 / np.arange(1, n_families + 1)          # Zipf popularity
    fam_p /= fam_p.sum()
    prefixes = [list(rng.integers(0, vocab, prefix_len))
                for _ in range(n_families)]
    reqs = []
    for _ in range(n_requests):
        fam = rng.choice(n_families, p=fam_p)
        reqs.append(prefixes[fam] + list(rng.integers(0, vocab, 8)))
    return reqs


def main() -> int:
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)

    # size the budget to 8 blocks so eviction pressure is real
    probe = ServeEngine(cfg, params, max_slots=1, max_seq=96,
                        pool_blocks=1)
    budget = probe._block_nbytes() * 8

    rng = np.random.default_rng(0)
    reqs = workload(cfg.vocab, rng)

    print(f"{len(reqs)} requests, 5 Zipf families, KV budget = "
          f"{budget/1024:.0f} KiB\n")
    for policy in ("lru", "lrc", "lerc"):
        store = PrefixStore(capacity_bytes=budget, policy=policy,
                            block_tokens=8)
        eng = ServeEngine(cfg, params, max_slots=3, max_seq=96, store=store)
        t0 = time.time()
        for r in reqs:
            eng.submit(list(r), max_new=4)
        eng.run()
        m = eng.metrics()
        print(f"{policy:5s}  engine-steps {m['engine_steps']:4d}   "
              f"prefill saved {m['prefill_saved_frac']:6.1%}   "
              f"chain-hit {m['hit_ratio']:5.1%}   "
              f"effective {m['effective_hit_ratio']:5.1%}   "
              f"({time.time()-t0:.1f}s)")
    print("\nfewer engine steps == less prefill compute; LERC keeps the "
          "popular family chains INTACT instead of fragmenting them")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

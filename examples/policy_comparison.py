"""Sweep every cache policy (incl. the Belady clairvoyant bound) over the
paper's workload at several cache sizes — compact reproduction of the
paper's Figs. 5-7 plus the frontier beyond it.

    PYTHONPATH=src python examples/policy_comparison.py
"""
from repro.sim import (ClusterSim, HardwareModel, multi_tenant_zip,
                       zip_access_trace)

POLICIES = ["lru", "fifo", "lfu", "lrc", "sticky", "lerc", "belady"]
N_JOBS, N_BLOCKS, N_WORKERS = 6, 50, 20


def run(policy, cache_gb):
    hw = HardwareModel(cache_bytes=int(cache_gb * 2 ** 30) // N_WORKERS,
                       disk_bw=25e6)
    sim = ClusterSim(N_WORKERS, hw, policy=policy)
    for dag, _ in multi_tenant_zip(n_jobs=N_JOBS, n_blocks=N_BLOCKS,
                                   n_workers=N_WORKERS):
        sim.submit(dag)
    sim.run(stages={0})
    trace = zip_access_trace(N_JOBS, N_BLOCKS) if policy == "belady" \
        else None
    return sim.run(stages={1}, belady_trace=trace)


def main() -> int:
    for gb in (1.5, 2.5, 4.0):
        print(f"\ncache {gb} GB  "
              f"({N_JOBS} tenants x {N_BLOCKS} block-pairs)")
        print(f"  {'policy':7s} {'makespan':>9s} {'hit':>7s} {'eff-hit':>8s}")
        rows = {}
        for p in POLICIES:
            r = run(p, gb)
            rows[p] = r
            print(f"  {p:7s} {r.makespan:8.2f}s {r.metrics.hit_ratio:7.1%} "
                  f"{r.metrics.effective_hit_ratio:8.1%}")
        base = rows["lru"].makespan
        print(f"  LERC vs LRU: {100*(1-rows['lerc'].makespan/base):.1f}% "
              f"faster; Belady bound "
              f"{100*(1-rows['belady'].makespan/base):.1f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Fig. 5 — experiment runtime under LRU / LRC / LERC vs cache size.

Reproduces the §IV EC2 experiment in the cluster simulator: 10 tenants ×
zip jobs (2 × 400 MB files, 100 blocks each, 8 GB total), 20 workers.
Paper's headline @5.3 GB: 284 s (LRU), 220 s (LRC), 179 s (LERC) —
LERC −37.0% vs LRU, −18.6% vs LRC. The reproduction target is the
*ordering and relative speedups*, not absolute EC2 seconds.
"""
from __future__ import annotations

from .common import (CACHE_SIZES_GB, POLICIES, print_table, run_multi_tenant,
                     save_results)


def main(policies=None, cache_sizes=None):
    policies = policies or POLICIES
    cache_sizes = cache_sizes or CACHE_SIZES_GB
    rows = []
    for cache_gb in cache_sizes:
        per = {}
        for pol in policies:
            r = run_multi_tenant(pol, cache_gb)
            per[pol] = r["makespan_s"]
            rows.append(r)
        if "lru" in per and "lerc" in per:
            speedup_lru = 100 * (per["lru"] - per["lerc"]) / per["lru"]
            speedup_lrc = (100 * (per["lrc"] - per["lerc"]) / per["lrc"]
                           if "lrc" in per else float("nan"))
            print(f"cache={cache_gb:4.1f}GB  LERC vs LRU: -{speedup_lru:.1f}%"
                  f"  LERC vs LRC: -{speedup_lrc:.1f}%"
                  f"  (paper @5.3GB: -37.0% / -18.6%)")
    print_table("Fig. 5 — makespan (s)", rows,
                ["policy", "cache_gb", "makespan_s", "hit_ratio",
                 "effective_hit_ratio"])
    save_results("fig5_makespan", rows)
    return rows


if __name__ == "__main__":
    main()

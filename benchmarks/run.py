"""Benchmark harness — one entry per paper table/figure, plus the
framework-level benches (prefix cache, roofline extraction).

Usage:
    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run fig5         # one benchmark
"""
from __future__ import annotations

import sys
import time


def _bench(name, fn):
    t0 = time.time()
    print(f"\n######## {name} ########")
    fn()
    print(f"[{name}] done in {time.time() - t0:.1f}s")


def main(argv=None) -> None:
    argv = argv if argv is not None else sys.argv[1:]
    from . import fig3_all_or_nothing, fig5_makespan, fig6_fig7_hit_ratios
    registry = {
        "fig3": fig3_all_or_nothing.main,
        "fig5": fig5_makespan.main,
        "fig6_fig7": fig6_fig7_hit_ratios.main,
    }
    for mod, key in (("policy_frontier", "policy_frontier"),
                     ("group_size_scaling", "group_size"),
                     ("eviction_scaling", "eviction_scaling"),
                     ("prefix_cache_bench", "prefix_cache"),
                     ("serve_throughput", "serve_throughput"),
                     ("tiered_serve", "tiered_serve"),
                     ("coordination_overhead", "coordination_overhead"),
                     ("pipeline_bench", "pipeline"),
                     ("roofline", "roofline")):
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["main"])
            registry[key] = m.main
        except ImportError:
            pass

    wanted = argv or list(registry)
    for name in wanted:
        if name not in registry:
            raise SystemExit(f"unknown benchmark {name!r}; have {sorted(registry)}")
        _bench(name, registry[name])


if __name__ == "__main__":
    main()

"""Benchmark harness — one entry per paper table/figure, plus the
framework-level benches (prefix cache, roofline extraction).

Usage:
    PYTHONPATH=src python -m benchmarks.run                # everything
    PYTHONPATH=src python -m benchmarks.run fig5           # one benchmark
    PYTHONPATH=src python -m benchmarks.run --toy \
        serve_throughput serve_latency --json              # CI artifact

``--json PATH`` collects every executed benchmark's saved result rows
(benchmarks/results/<name>.json) into one artifact, so the perf
trajectory of the repo is a single machine-readable file per run.
``--toy`` runs benchmarks that support it at CI scale.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time

from .common import RESULTS_DIR


def _bench(name, fn, toy: bool) -> None:
    t0 = time.time()
    print(f"\n######## {name} ########")
    if toy and "toy" in inspect.signature(fn).parameters:
        fn(toy=True)
    else:
        fn()
    print(f"[{name}] done in {time.time() - t0:.1f}s")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("benchmarks", nargs="*",
                    help="benchmark names (default: all)")
    ap.add_argument("--toy", action="store_true",
                    help="CI scale for benchmarks that support it")
    ap.add_argument("--json", nargs="?", default=None,
                    const="BENCH_10.json", metavar="PATH",
                    help="write one artifact collecting every executed "
                         "benchmark's result rows (default path when the "
                         "flag is bare: BENCH_10.json at the repo root)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])

    from . import fig3_all_or_nothing, fig5_makespan, fig6_fig7_hit_ratios
    registry = {
        "fig3": fig3_all_or_nothing.main,
        "fig5": fig5_makespan.main,
        "fig6_fig7": fig6_fig7_hit_ratios.main,
    }
    # saved-result filenames, where they differ from the registry key
    result_names = {"fig3": "fig3_all_or_nothing", "fig5": "fig5_makespan",
                    "fig6_fig7": "fig6_fig7_hit_ratios",
                    "group_size": "group_size_scaling",
                    "pipeline": "pipeline_bench"}
    for mod, key in (("policy_frontier", "policy_frontier"),
                     ("group_size_scaling", "group_size"),
                     ("eviction_scaling", "eviction_scaling"),
                     ("prefix_cache_bench", "prefix_cache"),
                     ("serve_throughput", "serve_throughput"),
                     ("serve_latency", "serve_latency"),
                     ("tiered_serve", "tiered_serve"),
                     ("fault_recovery", "fault_recovery"),
                     ("coordination_overhead", "coordination_overhead"),
                     ("pipeline_bench", "pipeline"),
                     ("roofline", "roofline")):
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["main"])
            registry[key] = m.main
        except ImportError:
            pass

    wanted = args.benchmarks or list(registry)
    for name in wanted:
        if name not in registry:
            raise SystemExit(f"unknown benchmark {name!r}; have {sorted(registry)}")
        _bench(name, registry[name], args.toy)

    if args.json:
        artifact = {"toy": args.toy, "benchmarks": {}}
        for name in wanted:
            path = os.path.join(RESULTS_DIR,
                                f"{result_names.get(name, name)}.json")
            if os.path.exists(path):
                with open(path) as f:
                    artifact["benchmarks"][name] = json.load(f)
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"\nwrote {args.json} "
              f"({sorted(artifact['benchmarks'])})")


if __name__ == "__main__":
    main()

"""Serve hot-path throughput: legacy (token-at-a-time, host-payload KV)
vs the PR 2 data plane (chunked prefill + device-resident paged KV pool).

Shared-prefix workload on the real smoke model. Reports engine steps
(jitted dispatches), wall-clock, and end-to-end tokens/s for each engine;
the acceptance target is >=3x tokens/s and >=4x fewer prefill dispatches
at prefill_chunk=8. Each engine is warmed on a tiny throwaway workload
first so compile time is excluded from the measured window.
"""
from __future__ import annotations

import time

import numpy as np

from .common import print_table, save_results

# prefill-dominated shape: this PR optimizes the prompt hot path (decode
# steps cost the same in both engines and would dilute the signal)
N_REQUESTS = 16
N_FAMILIES = 4
PREFIX = 72
SUFFIX = 8
MAX_NEW = 4
MAX_SEQ = 128
BT = 8


def _workload(vocab, seed=0):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, PREFIX))
                for _ in range(N_FAMILIES)]
    return [prefixes[i % N_FAMILIES]
            + list(rng.integers(0, vocab, SUFFIX))
            for i in range(N_REQUESTS)]


def _run(make_engine, reqs) -> dict:
    # warm-up: run the FULL workload on a throwaway engine so every
    # (batch, chunk, pool-transfer) specialization is compiled before the
    # measured window (jitted fns are shared per-config across engines)
    warm = make_engine()
    for r in reqs:
        warm.submit(r, max_new=MAX_NEW)
    warm.run()
    # best-of-3: CPU wall-clock noise at smoke scale rivals the signal
    wall = float("inf")
    for _ in range(3):
        eng = make_engine()
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r, max_new=MAX_NEW)
        eng.run()
        wall = min(wall, time.perf_counter() - t0)
    m = eng.metrics()
    tokens = m["prefill_tokens"] + m["decoded_tokens"]
    return {
        "engine_steps": m["engine_steps"],
        "wall_s": round(wall, 3),
        "tokens": tokens,
        "tokens_per_s": round(tokens / wall, 1),
        "prefill_saved_frac": round(m["prefill_saved_frac"], 3),
        "evictions": m["evictions"],
    }


def main() -> None:
    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import LegacyServeEngine, PrefixStore, ServeEngine

    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                        dtype=cfg.dtype)
    reqs = _workload(cfg.vocab)

    probe = ServeEngine(cfg, params, max_slots=3, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    budget = probe._block_nbytes() * 16

    def legacy():
        return LegacyServeEngine(
            cfg, params, max_slots=3, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT))

    def pooled(chunk):
        return lambda: ServeEngine(
            cfg, params, max_slots=3, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT),
            prefill_chunk=chunk)

    rows = [{"engine": "legacy (host KV, chunk=1)", **_run(legacy, reqs)}]
    for chunk in (4, 8):
        rows.append({"engine": f"pooled (device KV, chunk={chunk})",
                     **_run(pooled(chunk), reqs)})
    print_table("Serve hot path: old vs new data plane", rows,
                ["engine", "engine_steps", "wall_s", "tokens",
                 "tokens_per_s", "prefill_saved_frac", "evictions"])
    save_results("serve_throughput", rows)

    base, best = rows[0], rows[-1]
    speedup = best["tokens_per_s"] / base["tokens_per_s"]
    step_ratio = base["engine_steps"] / best["engine_steps"]
    print(f"\npooled+chunked vs legacy: {speedup:.1f}x tokens/s, "
          f"{step_ratio:.1f}x fewer dispatches "
          f"(target: >=3x tokens/s at smoke scale)")


if __name__ == "__main__":
    main()

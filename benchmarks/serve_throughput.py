"""Serve hot-path throughput across the three data planes: legacy
(token-at-a-time, host-payload KV), gather (PR 2: chunked prefill +
gather/scatter against the device pool), and paged (PR 5: zero-copy block
tables, decode straight out of the pool).

Shared-prefix workload on the real smoke model. Reports engine steps,
KV-transfer dispatches (gathers/scatters/CoW copies), dispatches per
request, wall-clock, end-to-end tokens/s, and resident device KV bytes.
The paged arm's pool is sized to the *same device byte budget* the gather
arm spends on pool + per-slot contiguous caches, so the usable-pool-blocks
column shows what eliminating the per-slot cache buys. Acceptance targets:
>=1.3x tokens/s paged-vs-gather and >=1.5x usable pool blocks at equal
device bytes (plus the PR 2 target, >=3x pooled-vs-legacy). Each engine is
warmed on the full workload first so compile time is excluded.

With >=2 jax devices a fourth section runs the tensor-parallel arm
(PR 7): the paged engine at tp in {1, 2[, 4]} under the SAME per-device
byte budget, reporting tokens/s, usable pool blocks per device MiB, and
the collectives one compiled step issues (counted from the step's HLO).
Sharding every pool row over tp devices means the same device bytes hold
tp x the blocks — target >=1.8x blocks per device byte at tp=2 vs tp=1.
CPU recipe: XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from .common import print_table, save_results

# prefill-dominated shape: prompt processing is the serve hot path, and
# the paged plane additionally removes per-request transfer dispatches
N_REQUESTS = 16
N_FAMILIES = 4
PREFIX = 72
SUFFIX = 8
MAX_NEW = 4
MAX_SEQ = 128
MAX_SLOTS = 8       # throughput shape: wide continuous batches make the
                    # per-request transfer dispatches the gather plane
                    # pays (admission gather + publish scatter) a large
                    # share of total dispatches
BT = 8


def _workload(vocab, n_requests, seed=0):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, PREFIX))
                for _ in range(N_FAMILIES)]
    return [prefixes[i % N_FAMILIES]
            + list(rng.integers(0, vocab, SUFFIX))
            for i in range(n_requests)]


def _run_arms(arms, reqs, repeats=5):
    """Measure every (name, make_engine) arm best-of-N with the repeat
    loops *interleaved*, so a background-load spike penalizes all arms
    equally instead of whichever one it landed on. Returns the result
    rows plus each arm's last engine (for post-hoc inspection, e.g.
    counting a TP step's collectives from its HLO)."""
    # warm-up: run the FULL workload on a throwaway engine per arm so
    # every (batch, chunk, pool-transfer) specialization is compiled
    # before the measured window (jitted fns are shared per-config)
    for _, mk in arms:
        warm = mk()
        for r in reqs:
            warm.submit(r, max_new=MAX_NEW)
        warm.run()
    walls = {name: float("inf") for name, _ in arms}
    last = {}
    for _ in range(repeats):
        for name, mk in arms:
            eng = mk()
            t0 = time.perf_counter()
            for r in reqs:
                eng.submit(r, max_new=MAX_NEW)
            eng.run()
            walls[name] = min(walls[name], time.perf_counter() - t0)
            last[name] = eng
    rows = []
    for name, _ in arms:
        m = last[name].metrics()
        wall = walls[name]
        tokens = m["prefill_tokens"] + m["decoded_tokens"]
        transfers = m.get("kv_transfer_dispatches", 0)
        rows.append({
            "engine": name,
            "engine_steps": m["engine_steps"],
            "kv_transfers": transfers,
            "disp_per_req": round((m["engine_steps"] + transfers)
                                  / len(reqs), 1),
            "wall_s": round(wall, 3),
            "tokens": tokens,
            "tokens_per_s": round(tokens / wall, 1),
            "device_kv_kb": round(m.get("device_kv_bytes", 0) / 1024, 1),
            "pool_blocks": m.get("pool_blocks", 0),
            "syncs_avoided": m.get("host_syncs_avoided", 0),
            "prefill_saved_frac": round(m["prefill_saved_frac"], 3),
            "evictions": m["evictions"],
        })
    return rows, last


def _tp_section(toy: bool) -> tuple:
    """Tensor-parallel arm (PR 7): the paged engine at tp in {1, 2[, 4]}
    under the SAME per-device byte budget. Every pool row shards over the
    mesh, so one device's bytes back tp x the global blocks — the
    capacity behind every effective hit multiplies without the policy
    layer noticing. Skips (with a recipe) when only one device exists."""
    import re

    import jax
    from repro.models import init_params, model_spec
    from repro.models.common import ModelConfig
    from repro.serve import PrefixStore, ServeEngine

    if jax.device_count() < 2:
        print("\n[tp] skipped: need >=2 jax devices for the tensor-"
              "parallel arm (CPU recipe: XLA_FLAGS=--xla_force_host_"
              "platform_device_count=8)")
        return [], {}

    # qwen2_7b's smoke config has a single KV head (unshardable); the TP
    # arm needs its own GQA smoke shape — 8 query / 4 KV heads divides
    # over tp in {1, 2, 4}
    cfg = ModelConfig(arch="tp_bench", family="dense", n_layers=2,
                      d_model=32, n_heads=8, n_kv_heads=4, d_head=8,
                      d_ff=64, vocab=256, act="swiglu", layer_pattern="G")
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    # shorter prompts than the main sections: the per-slot tail-row
    # horizon must leave pool headroom inside the fixed byte budget
    prefix, suffix, slots = 40, 8, 4
    rng = np.random.default_rng(1)
    prefixes = [list(rng.integers(0, cfg.vocab, prefix))
                for _ in range(N_FAMILIES)]
    reqs = [prefixes[i % N_FAMILIES]
            + list(rng.integers(0, cfg.vocab, suffix))
            for i in range(8 if toy else N_REQUESTS)]

    probe = ServeEngine(cfg, params, max_slots=slots, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc",
                                          block_tokens=BT),
                        paged=True, pool_blocks=1)
    blk = probe.pool.block_nbytes
    # fixed PER-DEVICE budget: tp x the global rows fit in it at tp, so
    # the store may keep tp x the bytes before eviction pressure starts
    horizon = -(-(prefix + suffix + MAX_NEW) // BT)
    per_dev_budget = blk * (16 + slots * horizon + 1)
    tps = [1, 2] + ([4] if jax.device_count() >= 4 else [])

    def tp_arm(tp):
        nblocks = per_dev_budget * tp // blk
        return lambda: ServeEngine(
            cfg, params, max_slots=slots, max_seq=MAX_SEQ,
            store=PrefixStore(blk * (nblocks - slots * horizon - 1),
                              "lerc", block_tokens=BT),
            prefill_chunk=8, paged=True, pool_blocks=nblocks, tp=tp)

    rows, engines = _run_arms([(f"paged tp={t}", tp_arm(t)) for t in tps],
                              reqs, repeats=1 if toy else 8)
    for row in rows:
        eng = engines[row["engine"]]
        m = eng.metrics()
        dev_bytes = m["device_kv_bytes"]
        row["tp"] = m["serve_tp"]
        row["device_kv_kb"] = round(dev_bytes / 1024, 1)
        row["global_kv_kb"] = round(m["kv_bytes_global"] / 1024, 1)
        row["blocks_per_dev_mib"] = round(
            m["pool_blocks"] / (dev_bytes / 2**20), 1)
        # collectives ONE compiled engine step issues, straight from its
        # HLO — the cost side of the tp x capacity trade
        row["collectives_per_step"] = len(re.findall(
            r"(?:all-gather|all-reduce|collective-permute|all-to-all)\(",
            eng.step_hlo()))

    print_table("Tensor-parallel paged serving: same per-device bytes, "
                "tp x the blocks", rows,
                ["engine", "tp", "tokens_per_s", "pool_blocks",
                 "device_kv_kb", "global_kv_kb", "blocks_per_dev_mib",
                 "collectives_per_step", "prefill_saved_frac",
                 "evictions"])

    tp1, tp2 = rows[0], rows[1]
    density_ratio = (tp2["blocks_per_dev_mib"]
                     / max(tp1["blocks_per_dev_mib"], 1e-9))
    summary = {
        "tp2_vs_tp1_blocks_per_device_byte": round(density_ratio, 2),
        "tp2_collectives_per_step": tp2["collectives_per_step"],
        "tp1_collectives_per_step": tp1["collectives_per_step"],
        "tp_device_count": jax.device_count(),
    }
    print(f"\ntp=2 vs tp=1: {density_ratio:.1f}x usable pool blocks per "
          f"device byte at {tp2['device_kv_kb']:.0f} KiB/device "
          f"(target: >=1.8x); {tp2['collectives_per_step']} collectives "
          "per step")
    return rows, summary


def main(toy: bool = False) -> None:
    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import LegacyServeEngine, PrefixStore, ServeEngine

    n_requests = 8 if toy else N_REQUESTS
    repeats = 1 if toy else 12
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    reqs = _workload(cfg.vocab, n_requests)

    probe = ServeEngine(cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    # moderate pressure: the store still evicts (the O(1) index-free path
    # is on the measured path) without eviction bookkeeping — identical in
    # every arm — swamping the data-plane signal this benchmark targets
    budget = probe._block_nbytes() * 32

    def legacy():
        return LegacyServeEngine(
            cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT))

    def gather(chunk):
        return lambda: ServeEngine(
            cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT),
            prefill_chunk=chunk)

    # the paged arm may spend the gather arm's ENTIRE device KV byte
    # budget (pool + per-slot contiguous caches) on pool rows: same
    # bytes, many more usable blocks — what "hits are free" buys back.
    # It only ALLOCATES what this workload can touch (store budget +
    # per-slot tail rows for the request horizon): carrying dead rows
    # through every step would burn the very bytes-per-step the paged
    # plane saves.
    gprobe = gather(8)()
    gather_kv_bytes = gprobe.pool.nbytes + sum(
        leaf.nbytes for leaf in jax.tree.leaves(gprobe.cache))
    budget_blocks = int(gather_kv_bytes // probe._block_nbytes())
    horizon_rows = -(-(PREFIX + SUFFIX + MAX_NEW) // BT)
    paged_pool_blocks = min(budget_blocks,
                            32 + MAX_SLOTS * horizon_rows + 1)

    def paged():
        return ServeEngine(
            cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT),
            prefill_chunk=8, paged=True, pool_blocks=paged_pool_blocks)

    rows, _ = _run_arms(
        [("legacy (host KV, chunk=1)", legacy),
         ("gather (device pool, chunk=4)", gather(4)),
         ("gather (device pool, chunk=8)", gather(8)),
         ("paged (zero-copy block tables, chunk=8)", paged)],
        reqs, repeats)

    print_table("Serve hot path: legacy vs gather vs paged data plane",
                rows,
                ["engine", "engine_steps", "kv_transfers", "disp_per_req",
                 "wall_s", "tokens", "tokens_per_s", "device_kv_kb",
                 "pool_blocks", "syncs_avoided", "prefill_saved_frac",
                 "evictions"])

    base, gat, pag = rows[0], rows[-2], rows[-1]
    pooled_speedup = gat["tokens_per_s"] / base["tokens_per_s"]
    paged_speedup = pag["tokens_per_s"] / gat["tokens_per_s"]
    block_ratio = pag["pool_blocks"] / max(gat["pool_blocks"], 1)
    summary = {
        "pooled_vs_legacy_tokens_per_s": round(pooled_speedup, 2),
        "paged_vs_gather_tokens_per_s": round(paged_speedup, 2),
        "paged_vs_gather_pool_blocks": round(block_ratio, 2),
        "paged_device_kv_kb": pag["device_kv_kb"],
        "gather_device_kv_kb": gat["device_kv_kb"],
    }
    print(f"\npooled+chunked vs legacy: {pooled_speedup:.1f}x tokens/s "
          "(target: >=3x)")
    print(f"paged vs gather: {paged_speedup:.1f}x tokens/s, "
          f"{block_ratio:.1f}x usable pool blocks at "
          f"{pag['device_kv_kb']:.0f} vs {gat['device_kv_kb']:.0f} KiB "
          "device KV (targets: >=1.3x tokens/s, >=1.5x blocks)")

    tp_rows, tp_summary = _tp_section(toy)
    summary.update(tp_summary)
    save_results("serve_throughput", rows + tp_rows
                 + [{"engine": "summary", **summary}])


if __name__ == "__main__":
    main(toy="--toy" in sys.argv[1:])

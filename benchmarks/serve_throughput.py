"""Serve hot-path throughput across the three data planes: legacy
(token-at-a-time, host-payload KV), gather (PR 2: chunked prefill +
gather/scatter against the device pool), and paged (PR 5: zero-copy block
tables, decode straight out of the pool).

Shared-prefix workload on the real smoke model. Reports engine steps,
KV-transfer dispatches (gathers/scatters/CoW copies), dispatches per
request, wall-clock, end-to-end tokens/s, and resident device KV bytes.
The paged arm's pool is sized to the *same device byte budget* the gather
arm spends on pool + per-slot contiguous caches, so the usable-pool-blocks
column shows what eliminating the per-slot cache buys. Acceptance targets:
>=1.3x tokens/s paged-vs-gather and >=1.5x usable pool blocks at equal
device bytes (plus the PR 2 target, >=3x pooled-vs-legacy). Each engine is
warmed on the full workload first so compile time is excluded.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from .common import print_table, save_results

# prefill-dominated shape: prompt processing is the serve hot path, and
# the paged plane additionally removes per-request transfer dispatches
N_REQUESTS = 16
N_FAMILIES = 4
PREFIX = 72
SUFFIX = 8
MAX_NEW = 4
MAX_SEQ = 128
MAX_SLOTS = 8       # throughput shape: wide continuous batches make the
                    # per-request transfer dispatches the gather plane
                    # pays (admission gather + publish scatter) a large
                    # share of total dispatches
BT = 8


def _workload(vocab, n_requests, seed=0):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, PREFIX))
                for _ in range(N_FAMILIES)]
    return [prefixes[i % N_FAMILIES]
            + list(rng.integers(0, vocab, SUFFIX))
            for i in range(n_requests)]


def _run_arms(arms, reqs, repeats=5) -> list:
    """Measure every (name, make_engine) arm best-of-N with the repeat
    loops *interleaved*, so a background-load spike penalizes all arms
    equally instead of whichever one it landed on."""
    # warm-up: run the FULL workload on a throwaway engine per arm so
    # every (batch, chunk, pool-transfer) specialization is compiled
    # before the measured window (jitted fns are shared per-config)
    for _, mk in arms:
        warm = mk()
        for r in reqs:
            warm.submit(r, max_new=MAX_NEW)
        warm.run()
    walls = {name: float("inf") for name, _ in arms}
    last = {}
    for _ in range(repeats):
        for name, mk in arms:
            eng = mk()
            t0 = time.perf_counter()
            for r in reqs:
                eng.submit(r, max_new=MAX_NEW)
            eng.run()
            walls[name] = min(walls[name], time.perf_counter() - t0)
            last[name] = eng
    rows = []
    for name, _ in arms:
        m = last[name].metrics()
        wall = walls[name]
        tokens = m["prefill_tokens"] + m["decoded_tokens"]
        transfers = m.get("kv_transfer_dispatches", 0)
        rows.append({
            "engine": name,
            "engine_steps": m["engine_steps"],
            "kv_transfers": transfers,
            "disp_per_req": round((m["engine_steps"] + transfers)
                                  / len(reqs), 1),
            "wall_s": round(wall, 3),
            "tokens": tokens,
            "tokens_per_s": round(tokens / wall, 1),
            "device_kv_kb": round(m.get("device_kv_bytes", 0) / 1024, 1),
            "pool_blocks": m.get("pool_blocks", 0),
            "syncs_avoided": m.get("host_syncs_avoided", 0),
            "prefill_saved_frac": round(m["prefill_saved_frac"], 3),
            "evictions": m["evictions"],
        })
    return rows


def main(toy: bool = False) -> None:
    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import LegacyServeEngine, PrefixStore, ServeEngine

    n_requests = 8 if toy else N_REQUESTS
    repeats = 1 if toy else 12
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    reqs = _workload(cfg.vocab, n_requests)

    probe = ServeEngine(cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    # moderate pressure: the store still evicts (the O(1) index-free path
    # is on the measured path) without eviction bookkeeping — identical in
    # every arm — swamping the data-plane signal this benchmark targets
    budget = probe._block_nbytes() * 32

    def legacy():
        return LegacyServeEngine(
            cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT))

    def gather(chunk):
        return lambda: ServeEngine(
            cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT),
            prefill_chunk=chunk)

    # the paged arm may spend the gather arm's ENTIRE device KV byte
    # budget (pool + per-slot contiguous caches) on pool rows: same
    # bytes, many more usable blocks — what "hits are free" buys back.
    # It only ALLOCATES what this workload can touch (store budget +
    # per-slot tail rows for the request horizon): carrying dead rows
    # through every step would burn the very bytes-per-step the paged
    # plane saves.
    gprobe = gather(8)()
    gather_kv_bytes = gprobe.pool.nbytes + sum(
        leaf.nbytes for leaf in jax.tree.leaves(gprobe.cache))
    budget_blocks = int(gather_kv_bytes // probe._block_nbytes())
    horizon_rows = -(-(PREFIX + SUFFIX + MAX_NEW) // BT)
    paged_pool_blocks = min(budget_blocks,
                            32 + MAX_SLOTS * horizon_rows + 1)

    def paged():
        return ServeEngine(
            cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            store=PrefixStore(budget, "lerc", block_tokens=BT),
            prefill_chunk=8, paged=True, pool_blocks=paged_pool_blocks)

    rows = _run_arms(
        [("legacy (host KV, chunk=1)", legacy),
         ("gather (device pool, chunk=4)", gather(4)),
         ("gather (device pool, chunk=8)", gather(8)),
         ("paged (zero-copy block tables, chunk=8)", paged)],
        reqs, repeats)

    print_table("Serve hot path: legacy vs gather vs paged data plane",
                rows,
                ["engine", "engine_steps", "kv_transfers", "disp_per_req",
                 "wall_s", "tokens", "tokens_per_s", "device_kv_kb",
                 "pool_blocks", "syncs_avoided", "prefill_saved_frac",
                 "evictions"])

    base, gat, pag = rows[0], rows[-2], rows[-1]
    pooled_speedup = gat["tokens_per_s"] / base["tokens_per_s"]
    paged_speedup = pag["tokens_per_s"] / gat["tokens_per_s"]
    block_ratio = pag["pool_blocks"] / max(gat["pool_blocks"], 1)
    summary = {
        "pooled_vs_legacy_tokens_per_s": round(pooled_speedup, 2),
        "paged_vs_gather_tokens_per_s": round(paged_speedup, 2),
        "paged_vs_gather_pool_blocks": round(block_ratio, 2),
        "paged_device_kv_kb": pag["device_kv_kb"],
        "gather_device_kv_kb": gat["device_kv_kb"],
    }
    print(f"\npooled+chunked vs legacy: {pooled_speedup:.1f}x tokens/s "
          "(target: >=3x)")
    print(f"paged vs gather: {paged_speedup:.1f}x tokens/s, "
          f"{block_ratio:.1f}x usable pool blocks at "
          f"{pag['device_kv_kb']:.0f} vs {gat['device_kv_kb']:.0f} KiB "
          "device KV (targets: >=1.3x tokens/s, >=1.5x blocks)")
    save_results("serve_throughput", rows + [{"engine": "summary",
                                              **summary}])


if __name__ == "__main__":
    main(toy="--toy" in sys.argv[1:])

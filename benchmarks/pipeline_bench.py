"""Real-pipeline benchmark: the paper's zip workload on the actual
``repro.data`` executor with REAL disk spill I/O (not the simulator).
Reports wall-clock I/O seconds, bytes re-read from disk, and the two hit
ratios per policy — the mechanism end-to-end.
"""
from __future__ import annotations

import tempfile

import numpy as np

from repro.data import Executor, Pipeline

from .common import print_table, save_results

POLICIES = ["lru", "lrc", "lerc"]


def run(policy: str, n_pairs: int = 24, block_kb: int = 256,
        cache_blocks: int = 20):
    rng = np.random.default_rng(0)
    n = block_kb * 1024 // 4
    A = [rng.integers(0, 1 << 30, n).astype(np.int32)
         for _ in range(n_pairs)]
    B = [rng.integers(0, 1 << 30, n).astype(np.int32)
         for _ in range(n_pairs)]
    pipe = Pipeline("bench")
    ra = pipe.source(A, "A")
    rb = pipe.source(B, "B")
    rz = pipe.zip_([ra, rb], lambda a, b: a + b, "Z")
    with tempfile.TemporaryDirectory() as spill:
        ex = Executor(pipe, cache_bytes=cache_blocks * A[0].nbytes,
                      policy=policy, spill_dir=spill)
        ex.load_sources(ra)
        ex.load_sources(rb)
        ex.materialize(rz)
        return {
            "policy": policy,
            "hit_ratio": round(ex.metrics.hit_ratio, 3),
            "effective_hit_ratio": round(ex.metrics.effective_hit_ratio, 3),
            "disk_reread_mb": round(ex.stats.disk_read_bytes / 2 ** 20, 1),
            "io_seconds": round(ex.stats.io_seconds, 3),
        }


def main() -> None:
    rows = [run(p) for p in POLICIES]
    print_table("Real pipeline (disk spill) — policy comparison", rows,
                ["policy", "hit_ratio", "effective_hit_ratio",
                 "disk_reread_mb", "io_seconds"])
    save_results("pipeline_bench", rows)
    lerc = next(r for r in rows if r["policy"] == "lerc")
    lru = next(r for r in rows if r["policy"] == "lru")
    if lru["disk_reread_mb"] > 0:
        saved = 1 - lerc["disk_reread_mb"] / lru["disk_reread_mb"]
        print(f"\nLERC re-reads {saved:.1%} fewer bytes than LRU")


if __name__ == "__main__":
    main()

"""Tiered KV store: recompute vs promote, per tier × demotion dtype.

The tentpole claim of the tiered serve path (PR 4 + PR 8): when device
pressure pushes a prefix chain out of the fast tier, the slow tiers turn
the next reference from a full prefill recompute (~prefix/chunk model
dispatches) into one promotion copy — and *transcoding* the demotion
(int8/fp8 with per-block scales) multiplies how many chain blocks each
slow-tier byte holds, which by the paper's all-or-nothing argument is the
capacity that matters (complete chains per byte, not raw bytes).

Arms: a recompute baseline (no slow tiers), a host tier per quant format
under ONE fixed byte budget (so the blocks-per-MiB column shows what the
format buys), and a disk tier (tiny host, so re-references promote from
the memmap files) per format.

The model runs with an f32 KV cache: that is the dtype regime the ~4x
int8 claim prices (a bf16 cache halves the ratio — the quant layer's
``compression_ratio`` reports both honestly).

Acceptance targets at smoke scale: >=3x host-tier blocks per byte with
int8 demotion vs lossless, and disk-tier promotion TTFT >=2x lower than
prefill recompute.

    PYTHONPATH=src python -m benchmarks.tiered_serve [--toy]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from .common import print_table, save_results

BT = 8               # block_tokens
SUFFIX = 8
MAX_NEW = 4
MAX_SEQ = 160
CHUNK = 4            # prefill chunk: prefix recompute = ~PREFIX/CHUNK steps
HOST_BLOCKS = 32     # host byte budget, in LOSSLESS blocks (quant arms fit
#                      compression_ratio-times more rows in the same bytes)
DISK_HOST_BLOCKS = 3 # disk arms: host tier this small spills to disk
DISK_BLOCKS = 64     # disk byte budget, in lossless blocks


def _dev_blocks(prefix_tokens: int) -> int:
    """Device tier sized to hold ~one family: warming the next family
    forces the previous one out (demotion or death)."""
    return (prefix_tokens + SUFFIX) // BT + 3


def _families(vocab, n_families, prefix_tokens, seed=0):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, prefix_tokens))
                for _ in range(n_families)]
    suffixes = [list(rng.integers(0, vocab, SUFFIX)) for _ in range(2)]
    return prefixes, suffixes


def _ttft(eng, prompt):
    """Seconds from submit to the first generated token. The engine
    pipelines host readback (``generated`` fills lazily at completion),
    so first-token time is the step that *emits* token one —
    ``n_generated`` tracks that without forcing a device sync."""
    req = eng.submit(prompt, max_new=MAX_NEW)
    t0 = time.perf_counter()
    while not req.n_generated:
        eng.step()
    # the dispatch is async: the token exists once the step's output does
    jax.block_until_ready(eng._prev_out)
    dt = time.perf_counter() - t0
    eng.run()                       # drain the tail decode steps
    return dt


def _run_cycle(cfg, params, blk, dev_blocks, arm, prefixes,
               suffixes) -> dict:
    from repro.serve import ServeEngine, TieredKVStore

    store = TieredKVStore(blk * dev_blocks, "lerc", block_tokens=BT,
                          host_capacity_bytes=blk * arm["host_blocks"],
                          kv_quant=arm["quant"],
                          disk_capacity_bytes=blk * arm["disk_blocks"])
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=MAX_SEQ,
                      store=store, prefill_chunk=CHUNK)
    # warm every family once; later families demote (or evict) earlier ones
    for pfx in prefixes:
        eng.submit(pfx + suffixes[0], max_new=MAX_NEW)
    eng.run()
    # re-reference each family with a fresh suffix and time first token
    steps0, skipped0 = eng.steps, eng.prefill_tokens_skipped
    t0 = time.perf_counter()
    ttfts = [_ttft(eng, pfx + suffixes[1]) for pfx in prefixes]
    wall = time.perf_counter() - t0
    m = eng.metrics()
    hp, dp = eng.store.host_pool, eng.store.disk_pool
    mib = 1024 * 1024
    return {
        "tier": arm["tier"],
        "quant": arm["quant"] or "none",
        # rows the SAME byte budget bought, and rows-per-MiB at that
        # tier's transcoded block size — the lever under measurement
        "tier_blocks": (dp.num_blocks if dp is not None
                        else (hp.num_blocks if hp is not None else 0)),
        "blocks_per_mib": round(
            mib / (dp.block_nbytes if dp is not None
                   else (hp.block_nbytes if hp is not None
                         and hp.num_blocks else blk)), 1),
        "ttft_ms": round(1e3 * sum(ttfts) / len(ttfts), 1),
        "steps": eng.steps - steps0,
        "prefill_skipped": eng.prefill_tokens_skipped - skipped0,
        "promotions": m["promotions"],
        "disk_promotions": m["disk_promotions"],
        "quantized_demotions": m["quantized_demotions"],
        "tokens_per_s": round(
            (len(prefixes) * (len(prefixes[0]) + SUFFIX + MAX_NEW)) / wall,
            1),
    }


def main(argv=None, toy: bool = False) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--toy", action="store_true",
                    help="CI scale: fewer families, shorter prefixes")
    # argv=None means "called from benchmarks.run" (whose own flags are
    # not ours to parse); the CLI entry below passes sys.argv explicitly
    args = ap.parse_args(argv if argv is not None else [])
    args.toy = args.toy or toy

    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import PrefixStore, ServeEngine

    cfg = configs.get("qwen2_7b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype=jax.numpy.float32)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    n_families = 2 if args.toy else 4
    prefix_tokens = 48 if args.toy else 96
    prefixes, suffixes = _families(cfg.vocab, n_families, prefix_tokens)

    probe = ServeEngine(cfg, params, max_slots=1, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    blk = probe._block_nbytes()

    arms = [
        {"tier": "recompute", "quant": "none",
         "host_blocks": 0, "disk_blocks": 0},
        {"tier": "host", "quant": "none",
         "host_blocks": HOST_BLOCKS, "disk_blocks": 0},
        {"tier": "host", "quant": "int8",
         "host_blocks": HOST_BLOCKS, "disk_blocks": 0},
        {"tier": "host", "quant": "fp8",
         "host_blocks": HOST_BLOCKS, "disk_blocks": 0},
        {"tier": "disk", "quant": "none",
         "host_blocks": DISK_HOST_BLOCKS, "disk_blocks": DISK_BLOCKS},
        {"tier": "disk", "quant": "int8",
         "host_blocks": DISK_HOST_BLOCKS, "disk_blocks": DISK_BLOCKS},
    ]
    if args.toy:
        arms = [a for a in arms if a["quant"] != "fp8"]

    # warm-up: compile every (chunk, transfer-size) specialization outside
    # the measured window (jitted fns are shared per-config)
    dev_blocks = _dev_blocks(prefix_tokens)
    for arm in (arms[0], arms[1], arms[2], arms[-1]):
        _run_cycle(cfg, params, blk, dev_blocks, arm, prefixes, suffixes)

    rows = []
    for arm in arms:
        best = None
        for _ in range(2):          # best-of-2: smoke-scale wall noise
            r = _run_cycle(cfg, params, blk, dev_blocks, arm, prefixes,
                           suffixes)
            if best is None or r["ttft_ms"] < best["ttft_ms"]:
                best = r
        rows.append(best)
    print_table("Tiered serve: recompute vs promote, per tier x dtype "
                f"(re-referenced {prefix_tokens}-token prefixes, f32 KV, "
                f"device={dev_blocks} blk)",
                rows, ["tier", "quant", "tier_blocks", "blocks_per_mib",
                       "ttft_ms", "steps", "prefill_skipped", "promotions",
                       "disk_promotions", "quantized_demotions",
                       "tokens_per_s"])
    save_results("tiered_serve", rows)

    by = {(r["tier"], r["quant"]): r for r in rows}
    base = by[("recompute", "none")]["ttft_ms"]
    host_best = min(r["ttft_ms"] for r in rows if r["tier"] == "host")
    bpb = (by[("host", "int8")]["blocks_per_mib"]
           / by[("host", "none")]["blocks_per_mib"])
    disk_ttft = min(r["ttft_ms"] for r in rows if r["tier"] == "disk")
    print(f"\nhost-tier blocks per byte, int8 vs lossless: {bpb:.2f}x "
          f"(target: >=3x with an f32 KV cache)")
    print(f"host promote vs recompute TTFT: {base / host_best:.1f}x lower")
    print(f"disk promote vs recompute TTFT: {base / disk_ttft:.1f}x lower "
          f"(target: >=2x at smoke scale)")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])

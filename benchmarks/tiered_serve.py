"""Tiered KV store: recompute vs promote on re-referenced evicted prefixes.

The tentpole claim of the tiered serve path (PR 4): when device pressure
pushes a prefix chain out of the fast tier, a host-memory tier turns the
next reference from a full prefill recompute (~prefix/chunk model
dispatches) into one host→device promotion copy. This benchmark warms K
prefix families through a device pool too small to hold them, then
re-references each family and measures time-to-first-token (TTFT) and
prefill dispatches, sweeping the host-tier size; ``--host-cache-kb 0``
(host_blocks=0) is the recompute baseline.

Acceptance target: >=2x lower TTFT for re-referenced evicted prefixes
with the host tier enabled vs disabled, at smoke scale.

    PYTHONPATH=src python -m benchmarks.tiered_serve [--toy]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .common import print_table, save_results

BT = 8               # block_tokens
SUFFIX = 8
MAX_NEW = 4
MAX_SEQ = 160
CHUNK = 4            # prefill chunk: prefix recompute = ~PREFIX/CHUNK steps


def _dev_blocks(prefix_tokens: int) -> int:
    """Device tier sized to hold ~one family: warming the next family
    forces the previous one out (demotion or death)."""
    return (prefix_tokens + SUFFIX) // BT + 3


def _families(vocab, n_families, prefix_tokens, seed=0):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, prefix_tokens))
                for _ in range(n_families)]
    suffixes = [list(rng.integers(0, vocab, SUFFIX)) for _ in range(2)]
    return prefixes, suffixes


def _ttft(eng, prompt):
    """Seconds from submit to the first generated token. The engine
    pipelines host readback (``generated`` fills lazily at completion),
    so first-token time is the step that *emits* token one —
    ``n_generated`` tracks that without forcing a device sync."""
    req = eng.submit(prompt, max_new=MAX_NEW)
    t0 = time.perf_counter()
    while not req.n_generated:
        eng.step()
    # the dispatch is async: the token exists once the step's output does
    jax.block_until_ready(eng._prev_out)
    dt = time.perf_counter() - t0
    eng.run()                       # drain the tail decode steps
    return dt


def _run_cycle(cfg, params, blk, dev_blocks, host_blocks, prefixes,
               suffixes) -> dict:
    from repro.serve import ServeEngine, TieredKVStore

    store = TieredKVStore(blk * dev_blocks, "lerc", block_tokens=BT,
                          host_capacity_bytes=blk * host_blocks)
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=MAX_SEQ,
                      store=store, prefill_chunk=CHUNK)
    # warm every family once; later families demote (or evict) earlier ones
    for pfx in prefixes:
        eng.submit(pfx + suffixes[0], max_new=MAX_NEW)
    eng.run()
    # re-reference each family with a fresh suffix and time first token
    steps0, skipped0 = eng.steps, eng.prefill_tokens_skipped
    t0 = time.perf_counter()
    ttfts = [_ttft(eng, pfx + suffixes[1]) for pfx in prefixes]
    wall = time.perf_counter() - t0
    m = eng.metrics()
    return {
        "host_blocks": host_blocks,
        "ttft_ms": round(1e3 * sum(ttfts) / len(ttfts), 1),
        "steps": eng.steps - steps0,
        "prefill_skipped": eng.prefill_tokens_skipped - skipped0,
        "demotions": m["demotions"],
        "promotions": m["promotions"],
        "host_evictions": m["host_evictions"],
        "tokens_per_s": round(
            (len(prefixes) * (len(prefixes[0]) + SUFFIX + MAX_NEW)) / wall,
            1),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--toy", action="store_true",
                    help="CI scale: fewer families, shorter prefixes")
    args = ap.parse_args(argv)

    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import PrefixStore, ServeEngine

    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    n_families = 2 if args.toy else 4
    prefix_tokens = 48 if args.toy else 96
    host_sizes = (0, 32) if args.toy else (0, 32, 64, 128)
    prefixes, suffixes = _families(cfg.vocab, n_families, prefix_tokens)

    probe = ServeEngine(cfg, params, max_slots=1, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    blk = probe._block_nbytes()

    # warm-up: compile every (chunk, transfer-size) specialization outside
    # the measured window (jitted fns are shared per-config)
    dev_blocks = _dev_blocks(prefix_tokens)
    for hb in {0, host_sizes[-1]}:
        _run_cycle(cfg, params, blk, dev_blocks, hb, prefixes, suffixes)

    rows = []
    for hb in host_sizes:
        best = None
        for _ in range(2):          # best-of-2: smoke-scale wall noise
            r = _run_cycle(cfg, params, blk, dev_blocks, hb, prefixes,
                           suffixes)
            if best is None or r["ttft_ms"] < best["ttft_ms"]:
                best = r
        rows.append(best)
    print_table("Tiered serve: recompute vs promote (re-referenced "
                f"{prefix_tokens}-token prefixes, device={dev_blocks} blk)",
                rows, ["host_blocks", "ttft_ms", "steps", "prefill_skipped",
                       "demotions", "promotions", "host_evictions",
                       "tokens_per_s"])
    save_results("tiered_serve", rows)

    base = rows[0]["ttft_ms"]
    best = min(r["ttft_ms"] for r in rows[1:])
    print(f"\npromote vs recompute TTFT: {base / best:.1f}x lower "
          f"(target: >=2x at smoke scale)")


if __name__ == "__main__":
    main()

"""Latency under SLOs: the serve front door across schedulers × policies.

One seeded Poisson arrival trace (short-majority prompt mix, TTFT
deadlines proportional to prompt length) replayed through six arms —
{fcfs, decode-first, budgeted} schedulers × {lru, lerc} stores — on the
engine's deterministic virtual clock. Reports TTFT/TPOT p50/p95/p99 and
goodput-under-deadline per arm.

What the arms isolate:

* **fcfs** processor-shares prefill: every prefilling slot feeds its full
  chunk every step, so each step costs ``base + per_token * (slots *
  chunk)`` and *everyone's* first token waits for everyone else's
  prompt — the classic p95 TTFT collapse under a burst.
* **budgeted** spends at most ``--prefill-budget`` prompt tokens per
  step, earliest-deadline-first: urgent (short-deadline) prompts cut
  ahead, long prefills are preempted, and steps stay cheap, bounding
  both TTFT and the decode slots' TPOT.
* **lru vs lerc** turns on the cache dimension: the trace's prompts
  share prefix families and the store budget is sized *below* the
  working set, so only a policy that keeps chains complete
  (all-or-nothing) converts residency into skipped prefill — and
  skipped prefill into deadlines met.

Acceptance targets (ISSUE 6): budgeted >= 2x better p95 TTFT than fcfs
at equal offered load with TPOT p95 regressing <= 10%, and lerc >= lru
on goodput when the working set exceeds the pool.
"""
from __future__ import annotations

import sys

import numpy as np

from .common import print_table, save_results

MAX_SLOTS = 12
MAX_SEQ = 256
BT = 8              # block tokens
CHUNK = 16          # prefill chunk per slot -> fcfs can dispatch up to
                    # MAX_SLOTS * CHUNK = 192 prompt tokens per step
BUDGET = 32         # budgeted arm: at most 32 prompt tokens per step
MAX_NEW = 8
N_FAMILIES = 4      # shared-prefix families (the cache dimension)
SHORT, LONG = 24, 160
LONG_EVERY = 24     # 2 of 48 requests (4%) carry a long context
RATE = 1.05         # Poisson arrivals per virtual time unit: just past
                    # the knee, where bursts inflate the fcfs tail but
                    # the system still drains (not sustained overload —
                    # there, work conservation converges every scheduler
                    # to the same backlog-drain p95)
# TTFT SLO proportional to prompt length: a short prompt expects its
# first token quickly, a long one buys itself slack
DEADLINE_BASE, DEADLINE_PER_TOK = 3.0, 0.10


def _trace(vocab, n_requests, seed=0):
    from repro.serve import TracedRequest
    from repro.sim import poisson_arrivals

    rng = np.random.default_rng(seed)
    times = poisson_arrivals(n_requests, RATE, seed)
    prefixes = [list(rng.integers(0, vocab, SHORT - BT))
                for _ in range(N_FAMILIES)]
    out = []
    for i, t in enumerate(times):
        long = i % LONG_EVERY == 0
        pfx = prefixes[i % N_FAMILIES]
        tail = LONG - len(pfx) if long else BT
        prompt = pfx + list(rng.integers(0, vocab, tail))
        out.append(TracedRequest(
            t=float(t), prompt=prompt, max_new=MAX_NEW,
            deadline=DEADLINE_BASE + DEADLINE_PER_TOK * len(prompt)))
    return out


def main(toy: bool = False) -> None:
    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import (BudgetedScheduler, PrefixStore, ServeEngine,
                             latency_stats, play_trace)

    n_requests = 16 if toy else 48
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    trace = _trace(cfg.vocab, n_requests)

    # store budget below the working set: N_FAMILIES shared prefixes plus
    # every request's private tail blocks compete for ~20 chain blocks,
    # so the eviction policy decides which prefixes stay *complete*
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    budget_bytes = probe._block_nbytes() * 20

    def make(policy, scheduler):
        return ServeEngine(
            cfg, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
            store=PrefixStore(budget_bytes, policy, block_tokens=BT),
            prefill_chunk=CHUNK, paged=True, scheduler=scheduler)

    arms = [(sched_name, policy,
             lambda p=policy, s=sched_name: make(
                 p, BudgetedScheduler(BUDGET) if s == "budgeted" else s))
            for sched_name in ("fcfs", "decode-first", "budgeted")
            for policy in ("lru", "lerc")]

    rows = []
    for sched_name, policy, mk in arms:
        eng = mk()
        report = play_trace(eng, trace)
        stats = latency_stats(report)
        m = eng.metrics()
        rows.append({
            "scheduler": sched_name, "policy": policy, **stats,
            "prefill_saved_frac": round(m["prefill_saved_frac"], 3),
            "virtual_time": round(m["virtual_time"], 1),
            "evictions": m["evictions"],
        })

    print_table("Serve latency under SLOs: scheduler x eviction policy",
                rows,
                ["scheduler", "policy", "goodput", "ttft_p50", "ttft_p95",
                 "ttft_p99", "tpot_p50", "tpot_p95", "prefill_saved_frac",
                 "virtual_time", "evictions"])

    # ---- traced-overhead arm (obs PR): the budgeted/lerc configuration
    # once more, untraced vs traced, on a warm jit cache — the recorder's
    # cost is pure Python per instrumentation site, so the wall ratio is
    # the "tracing enabled" overhead headline (target <= 1.05x; reported,
    # not asserted — CI wall clocks are noisy)
    import time as _time

    from benchmarks.trace_report import latency_from_trace
    from repro.obs import TraceRecorder

    t0 = _time.perf_counter()
    eng_off = make("lerc", BudgetedScheduler(BUDGET))
    play_trace(eng_off, trace)
    wall_off = _time.perf_counter() - t0

    recorder = TraceRecorder()
    eng_on = make("lerc", BudgetedScheduler(BUDGET))
    eng_on.attach_trace(recorder)
    t0 = _time.perf_counter()
    report_on = play_trace(eng_on, trace)
    wall_on = _time.perf_counter() - t0
    eng_on.metrics()      # runs the attribution conservation check
    # the report a human would read from the trace file must say exactly
    # what the live accounting said (deterministic: virtual clock)
    recon = latency_from_trace(recorder.export()["traceEvents"])
    live = latency_stats(report_on)
    assert recon == live, f"trace-reconstructed stats diverge:\n" \
                          f"  trace: {recon}\n  live:  {live}"
    trace_overhead = wall_on / max(wall_off, 1e-9)
    print(f"\ntracing overhead: {wall_on:.2f}s traced vs {wall_off:.2f}s "
          f"untraced = {trace_overhead:.3f}x "
          f"({recorder.n_emitted} events; target <=1.05x); "
          "trace-reconstructed latency stats match live: OK")

    by = {(r["scheduler"], r["policy"]): r for r in rows}
    fcfs, bud = by[("fcfs", "lerc")], by[("budgeted", "lerc")]
    ttft_ratio = fcfs["ttft_p95"] / max(bud["ttft_p95"], 1e-9)
    tpot_regress = bud["tpot_p95"] / max(fcfs["tpot_p95"], 1e-9)
    lerc_good = by[("budgeted", "lerc")]["goodput"]
    lru_good = by[("budgeted", "lru")]["goodput"]
    summary = {
        "budgeted_vs_fcfs_ttft_p95": round(ttft_ratio, 2),
        "budgeted_tpot_p95_regress": round(tpot_regress, 2),
        "lerc_goodput": lerc_good,
        "lru_goodput": lru_good,
        "trace_overhead_x": round(trace_overhead, 3),
        "trace_events": recorder.n_emitted,
    }
    print(f"\nbudgeted vs fcfs (lerc): {ttft_ratio:.1f}x better p95 TTFT "
          "(target: >=2x), TPOT p95 regress "
          f"{tpot_regress:.2f}x (target: <=1.10x)")
    print(f"goodput under deadline (budgeted): lerc {lerc_good:.3f} vs "
          f"lru {lru_good:.3f} (target: lerc >= lru)")
    save_results("serve_latency",
                 rows + [{"scheduler": "summary", **summary}])


if __name__ == "__main__":
    main(toy="--toy" in sys.argv[1:])

"""Paper Figs. 6 & 7 — cache hit ratio and effective cache hit ratio under
LRU / LRC / LERC vs cache size (§IV-B).

Expected reproduction:
  * Fig. 6: LRC attains the highest plain hit ratio; LERC close behind
    (it deliberately gives up ineffective hits); LRU lowest.
  * Fig. 7: LERC attains the highest *effective* hit ratio at every cache
    size; LRU is near zero (later-arriving second files evict the keys);
    LRC approaches LERC only as the cache grows.
  * The §IV-B conclusion: effective hit ratio tracks job runtime;
    plain hit ratio does not (LRC > LERC in Fig. 6 yet slower in Fig. 5).
"""
from __future__ import annotations

from .common import (CACHE_SIZES_GB, POLICIES, print_table, run_multi_tenant,
                     save_results)


def main(policies=None, cache_sizes=None):
    policies = policies or POLICIES
    cache_sizes = cache_sizes or CACHE_SIZES_GB
    rows = []
    for cache_gb in cache_sizes:
        for pol in policies:
            rows.append(run_multi_tenant(pol, cache_gb))
    print_table("Figs. 6 & 7 — hit ratio / effective hit ratio", rows,
                ["policy", "cache_gb", "hit_ratio", "effective_hit_ratio",
                 "makespan_s"])
    save_results("fig6_fig7_hit_ratios", rows)

    # §IV-B relevance check: within each cache size, ranking by effective
    # hit ratio must match ranking by (negative) makespan better than the
    # plain hit ratio does.
    agree_eff = agree_hit = total = 0
    for cache_gb in cache_sizes:
        sub = [r for r in rows if r["cache_gb"] == cache_gb]
        for i in range(len(sub)):
            for j in range(i + 1, len(sub)):
                a, b = sub[i], sub[j]
                if a["makespan_s"] == b["makespan_s"]:
                    continue
                faster_is = a if a["makespan_s"] < b["makespan_s"] else b
                slower_is = b if faster_is is a else a
                total += 1
                if faster_is["effective_hit_ratio"] >= slower_is["effective_hit_ratio"]:
                    agree_eff += 1
                if faster_is["hit_ratio"] >= slower_is["hit_ratio"]:
                    agree_hit += 1
    print(f"\nmetric→runtime agreement: effective_hit_ratio {agree_eff}/{total}, "
          f"plain hit_ratio {agree_hit}/{total} "
          f"(paper's claim: effective ratio is the more relevant metric)")
    return rows


if __name__ == "__main__":
    main()

"""Eviction-substrate scaling: incremental ERC index vs brute-force rescan.

The acceptance benchmark for the unified eviction substrate. Workload: a
prefix store holding ``n_resident`` KV blocks with ``n_chains`` pending
request chains over a Zipf family set; we then stream in cold chains,
forcing a fixed number of evictions, and time the eviction-heavy insert
phase for

* ``PrefixStore``           — shared incremental substrate (DagState
  counters + EvictionIndex): O(log n + degree) per eviction;
* ``ReferencePrefixStore``  — the seed algorithm, retained as the oracle:
  re-derives counts from ALL pending chains and rescans ALL resident
  nodes on EVERY victim — O(chains × depth + resident) per eviction.

Both implementations make bit-identical eviction decisions (proved by
tests/test_prefix_oracle.py and asserted again here), so the speedup is
pure substrate. Target: ≥5× at 10k resident blocks / 1k pending chains;
the per-eviction cost of the incremental store should be roughly flat in
n while the brute-force cost grows linearly.
"""
from __future__ import annotations

import random
import time

from repro.serve import PrefixStore, ReferencePrefixStore

from .common import print_table, save_results

DEPTH = 8            # blocks per chain
N_CHAINS = 1_000     # pending request chains
N_EVICT = 200        # evictions in the timed phase
POLICY = "lerc"


def _build(store_cls, n_resident: int, seed: int = 0):
    """Fill ``n_resident`` blocks, register ``N_CHAINS`` pending chains."""
    rng = random.Random(seed)
    store = store_cls(capacity_bytes=n_resident, policy=POLICY,
                      block_tokens=1)
    payload = {"kv": None}
    # resident working set: distinct cold chains of DEPTH blocks each
    for i in range(n_resident // DEPTH):
        toks = [i * DEPTH + t for t in range(DEPTH)]
        store.insert(toks, [payload] * DEPTH, nbytes_per_block=1)
    # pending chains over a Zipf-ish family set of the resident prefixes
    n_families = 100
    for _ in range(N_CHAINS):
        fam = int(rng.paretovariate(1.2)) % n_families
        toks = [fam * DEPTH + t for t in range(DEPTH)]
        store.register_request(toks)
    return store


def _timed_evictions(store, n_resident: int) -> float:
    """Insert cold chains until N_EVICT evictions happened; returns secs."""
    base = 10 * n_resident          # token ids disjoint from the build set
    start_ev = store.evictions
    payload = {"kv": None}
    t0 = time.perf_counter()
    i = 0
    while store.evictions - start_ev < N_EVICT:
        toks = [base + i * DEPTH + t for t in range(DEPTH)]
        store.insert(toks, [payload] * DEPTH, nbytes_per_block=1)
        i += 1
    return time.perf_counter() - t0


def run(n_resident: int) -> dict:
    inc = _build(PrefixStore, n_resident)
    ref = _build(ReferencePrefixStore, n_resident)
    t_inc = _timed_evictions(inc, n_resident)
    t_ref = _timed_evictions(ref, n_resident)
    assert inc.eviction_log == ref.eviction_log, \
        "substrates diverged — oracle equivalence violated"
    evs = inc.evictions
    return {
        "resident_blocks": n_resident,
        "pending_chains": N_CHAINS,
        "evictions": evs,
        "incremental_s": round(t_inc, 4),
        "bruteforce_s": round(t_ref, 4),
        "us_per_evict_inc": round(1e6 * t_inc / N_EVICT, 1),
        "us_per_evict_brute": round(1e6 * t_ref / N_EVICT, 1),
        "speedup": round(t_ref / t_inc, 1),
    }


def main() -> None:
    rows = [run(n) for n in (2_500, 5_000, 10_000)]
    print_table("Eviction substrate scaling (LERC, identical decisions)",
                rows, ["resident_blocks", "pending_chains", "evictions",
                       "incremental_s", "bruteforce_s", "us_per_evict_inc",
                       "us_per_evict_brute", "speedup"])
    save_results("eviction_scaling", rows)
    final = rows[-1]
    print(f"\nAt {final['resident_blocks']} resident blocks / "
          f"{final['pending_chains']} pending chains the incremental index "
          f"is {final['speedup']}x faster per eviction; its per-eviction "
          f"cost is ~flat across the sweep while the brute-force rescan "
          f"grows with n (acceptance target: >=5x).")
    assert final["speedup"] >= 5, "acceptance criterion not met"


if __name__ == "__main__":
    main()

"""Paper Fig. 3 — the all-or-nothing measurement study (§II-C).

One Spark zip job (Fig. 2 DAG): RDDs A and B, 10 blocks each (20 MB
blocks, 200 MB per RDD), on a 10-node cluster. Blocks are added to the
cache one at a time in the order A1, B1, A2, B2, …, A10, B10; after each
addition the zip stage is (re-)run and the total task runtime recorded.

Expected reproduction of the paper's figure: cache hit ratio grows
*linearly* with every cached block, but total task runtime drops only on
every *second* block — when a peer pair (Ai, Bi) completes. The staircase
is the all-or-nothing property.
"""
from __future__ import annotations

from repro.core import DagState
from repro.sim import ClusterSim, HardwareModel, zip_job

from .common import PAPER_HW, print_table, save_results

N_NODES = 10
N_BLOCKS = 10
BLOCK_MB = 20


def run_round(n_cached: int):
    hw = HardwareModel(cache_bytes=2 ** 40, **PAPER_HW)  # big cache; we
    sim = ClusterSim(N_NODES, hw, policy="lru")          # control contents
    dag, _ = zip_job("fig3", N_BLOCKS, BLOCK_MB * 2 ** 20, n_workers=N_NODES)
    sim.submit(dag)
    # caching order A1, B1, A2, B2, ... (paper §II-C)
    order = []
    for k in range(N_BLOCKS):
        order += [f"fig3.A[{k}]", f"fig3.B[{k}]"]
    cached = set(order[:n_cached])
    # materialize every input block: chosen ones into memory, rest to disk
    for b in order:
        mgr = sim.managers[sim.home[b]]
        if b in cached:
            mgr.insert(b, sim.dag.blocks[b].size)
        else:
            mgr.disk.put(b, sim.dag.blocks[b].size)
            sim.state.on_materialized(b, into_cache=False)
    for t in sim.dag.tasks.values():
        if t.stage == 0:
            sim._done.add(t.id)
    res = sim.run(stages={1})
    total_task_time = sum(res.task_runtimes.values())
    return {
        "blocks_cached": n_cached,
        "cache_hit_ratio": round(res.metrics.hit_ratio, 3),
        "effective_hit_ratio": round(res.metrics.effective_hit_ratio, 3),
        "total_task_runtime_s": round(total_task_time, 3),
    }


def main():
    rows = [run_round(n) for n in range(0, 2 * N_BLOCKS + 1)]
    print_table("Fig. 3 — all-or-nothing staircase", rows,
                ["blocks_cached", "cache_hit_ratio", "effective_hit_ratio",
                 "total_task_runtime_s"])
    save_results("fig3_all_or_nothing", rows)
    # the staircase property: runtime drops meaningfully only when a pair
    # completes (even counts), not when a half-pair is added (odd counts)
    drops = [rows[i]["total_task_runtime_s"] - rows[i + 1]["total_task_runtime_s"]
             for i in range(2 * N_BLOCKS)]
    odd_drops = sum(drops[0::2])    # adding A_i (half pair)
    even_drops = sum(drops[1::2])   # adding B_i (completes pair)
    print(f"\nruntime saved by half-pairs: {odd_drops:.3f}s; "
          f"by completed pairs: {even_drops:.3f}s")
    assert even_drops > 10 * max(odd_drops, 1e-9), \
        "staircase violated: half-pairs should not speed tasks up"
    return rows


if __name__ == "__main__":
    main()

"""Render reports from a serve trace (``repro.launch.serve --trace``).

Reads the Chrome/Perfetto trace-event JSON the ``repro.obs`` recorder
exports and prints, without importing the serving stack:

* a **TTFT waterfall** — per request: arrival, admission wait, time to
  first token, decode time, all on the engine's virtual clock;
* a **step-time breakdown** — wall time by engine phase (admit /
  dispatch / eos_sync / readback) from the ``X`` spans;
* **tier-flow counts** — a Sankey's edge list: how many blocks moved
  device→host, host→disk, disk→device, … and how many died per tier;
* **top ineffective-hit causes** — the headline analytic: which gaps
  (evicted / demoted-to-host / demoted-to-disk / never-cached) blocked
  otherwise-warm chains, summed from every ``store.lookup``;
* **bus traffic** by message kind;
* **latency stats reconstructed from the trace alone** — the same
  TTFT/TPOT percentiles and goodput ``repro.serve.latency_stats``
  computes live (``tests/test_obs.py`` asserts equality), from the
  request lifecycle events' args.

Usage:
  python -m benchmarks.trace_report trace.json
  python -m benchmarks.trace_report trace.json --check   # CI validation

``--check`` exits non-zero unless the file is valid trace-event JSON
with at least one complete request span — the CI gate for the traced
serve smoke.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List, Optional


def _pct(xs: List[float], q: float) -> float:
    """``np.percentile(..., q)`` with linear interpolation, dependency-
    free so the report runs anywhere, and 0.0 on an empty sample (the
    same NaN-free convention as ``repro.serve.latency_stats``)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (len(s) - 1) * q / 100.0
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (rank - lo))


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not a trace-event JSON object "
                         "(no 'traceEvents' key)")
    return doc


# --------------------------------------------------------------- extraction
def request_records(events: List[dict]) -> List[dict]:
    """One record per request whose lifecycle CLOSED inside the ring: the
    ``e`` event of the ``req`` async track carries everything
    ``latency_stats`` needs. Enriched with the admission time from the
    ``n``/"admitted" event when that survived the ring."""
    admitted_at: Dict[tuple, float] = {}
    out: List[dict] = []
    for ev in events:
        if ev.get("name") != "req" or "id" not in ev:
            continue
        key = (ev.get("pid", 0), ev["id"])
        args = ev.get("args") or {}
        if ev["ph"] == "n" and args.get("event") == "admitted":
            admitted_at[key] = ev.get("ts", 0.0)
        elif ev["ph"] == "e":
            out.append({**args, "_key": key})
    for r in out:
        r["admitted_ts"] = admitted_at.get(r["_key"])
    return out


def rejected_count(events: List[dict]) -> int:
    return sum(1 for ev in events if ev.get("ph") == "i"
               and ev.get("name") == "rejected")


def retried_count(events: List[dict]) -> int:
    """Bounces the trace loop re-offered (``sched.retry`` instants);
    the engine's ``rejected`` instant fires for those too, so final
    rejections are ``rejected_count - retried_count``."""
    return sum(1 for ev in events if ev.get("ph") == "i"
               and ev.get("name") == "sched.retry")


def latency_from_trace(events: List[dict]) -> Dict[str, float]:
    """Reconstruct ``repro.serve.latency_stats`` from the trace alone —
    identical keys, identical rounding."""
    reqs = request_records(events)
    ttft = [r["first_token_at"] - r["arrival"] for r in reqs
            if r.get("first_token_at") is not None]
    tpot = [(r["finished_at"] - r["first_token_at"]) / (r["n_generated"] - 1)
            for r in reqs
            if r.get("finished_at") is not None
            and r.get("first_token_at") is not None
            and r.get("n_generated", 0) > 1]
    met = 0
    for r in reqs:
        if r.get("cancelled") or r.get("first_token_at") is None:
            continue
        if r.get("deadline") is None:
            met += r.get("finished_at") is not None
        else:
            met += r["first_token_at"] <= r["deadline"]
    retried = retried_count(events)
    rejected = rejected_count(events) - retried
    offered = len(reqs) + rejected
    out = {"n_offered": offered, "n_rejected": rejected,
           "n_retried": retried,
           "goodput": round(float(met) / max(offered, 1), 4)}
    for name, xs in (("ttft", ttft), ("tpot", tpot)):
        for q in (50, 95, 99):
            out[f"{name}_p{q}"] = round(_pct(xs, q), 4)
    return out


def step_breakdown(events: List[dict]) -> Dict[str, dict]:
    out: Dict[str, dict] = defaultdict(lambda: {"n": 0, "total_us": 0.0})
    for ev in events:
        if ev.get("ph") != "X":
            continue
        rec = out[ev["name"]]
        rec["n"] += 1
        rec["total_us"] += ev.get("dur", 0.0)
    return dict(out)


def tier_flows(events: List[dict]) -> Dict[tuple, int]:
    """Sankey edge counts from the store's move instants. Eviction
    instants come in two arg shapes: tier-0 kills carry ``tier: 0``
    (plain-store path), slow-tier kills carry ``src`` with no ``dst``."""
    flows: Dict[tuple, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") != "i":
            continue
        name, args = ev.get("name"), ev.get("args") or {}
        if name in ("store.demote", "store.promote"):
            flows[(args.get("src", "?"), args.get("dst", "?"))] += 1
        elif name == "store.evict":
            src = args.get("src", "device" if args.get("tier", 0) == 0
                            else "?")
            flows[(src, "dead")] += 1
    return dict(flows)


def ineffective_causes(events: List[dict]) -> Dict[str, int]:
    causes: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "store.lookup":
            for cause, n in ((ev.get("args") or {})
                             .get("ineffective", {}) or {}).items():
                causes[cause] += int(n)
    return dict(causes)


def bus_traffic(events: List[dict]) -> Dict[str, dict]:
    out: Dict[str, dict] = defaultdict(lambda: {"n": 0, "bytes": 0})
    for ev in events:
        name = ev.get("name", "")
        if ev.get("ph") == "i" and name.startswith("bus."):
            rec = out[name[len("bus."):]]
            rec["n"] += 1
            rec["bytes"] += (ev.get("args") or {}).get("bytes", 0)
    return dict(out)


# ----------------------------------------------------------------- reporting
def print_report(doc: dict, top: int = 20) -> None:
    events = doc["traceEvents"]
    other = doc.get("otherData", {})
    print(f"trace: {len(events)} events  timebase={other.get('timebase')}"
          f"  emitted={other.get('events_emitted')}"
          f"  dropped={other.get('events_dropped')}")

    reqs = sorted(request_records(events),
                  key=lambda r: r.get("arrival", 0.0))
    if reqs:
        print(f"\n== TTFT waterfall ({len(reqs)} requests, virtual clock) ==")
        print(f"  {'rid':>6} {'arrival':>10} {'ttft':>10} {'decode':>10} "
              f"{'tokens':>6}  flags")
        for r in reqs[:top]:
            ft, fin = r.get("first_token_at"), r.get("finished_at")
            ttft = (ft - r["arrival"]) if ft is not None else None
            dec = (fin - ft) if ft is not None and fin is not None else None
            flags = []
            if r.get("cancelled"):
                flags.append("cancelled")
            if r.get("deadline") is not None and ft is not None \
                    and ft > r["deadline"]:
                flags.append("late")
            if r.get("prefill_skipped"):
                flags.append(f"skip={r['prefill_skipped']}")
            print(f"  {r.get('rid', '?'):>6} {r.get('arrival', 0):>10.3f} "
                  f"{ttft if ttft is not None else float('nan'):>10.3f} "
                  f"{dec if dec is not None else float('nan'):>10.3f} "
                  f"{r.get('n_generated', 0):>6}  {' '.join(flags)}")
        if len(reqs) > top:
            print(f"  ... {len(reqs) - top} more (--top to widen)")

    steps = step_breakdown(events)
    if steps:
        print("\n== step-time breakdown (wall, from X spans) ==")
        order = sorted(steps, key=lambda k: -steps[k]["total_us"])
        for name in order:
            rec = steps[name]
            mean = rec["total_us"] / max(rec["n"], 1)
            print(f"  {name:12s} n={rec['n']:<7} "
                  f"total={rec['total_us'] / 1e3:10.2f}ms "
                  f"mean={mean:8.1f}us")

    flows = tier_flows(events)
    if flows:
        print("\n== tier flows (blocks) ==")
        for (src, dst), n in sorted(flows.items(), key=lambda kv: -kv[1]):
            print(f"  {src:>7} -> {str(dst):7s} {n}")

    causes = ineffective_causes(events)
    if causes:
        print("\n== ineffective-hit causes (blocked warm blocks) ==")
        total = sum(causes.values())
        for cause, n in sorted(causes.items(), key=lambda kv: -kv[1]):
            print(f"  {cause:14s} {n:8d}  ({100.0 * n / total:5.1f}%)")

    bus = bus_traffic(events)
    if bus:
        print("\n== bus traffic ==")
        for kind, rec in sorted(bus.items(), key=lambda kv: -kv[1]["n"]):
            print(f"  {kind:16s} n={rec['n']:<8} bytes={rec['bytes']}")

    print("\n== latency stats (reconstructed from trace) ==")
    for k, v in latency_from_trace(events).items():
        print(f"  {k:12s} {v}")


def check(doc: dict) -> List[str]:
    """CI validation: Perfetto-loadable shape + nonempty request spans.
    Returns a list of problems (empty = pass)."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            problems.append(f"event {i}: missing ph/name")
            break
        if ev["ph"] != "M" and "ts" not in ev:
            problems.append(f"event {i} ({ev['name']}): missing ts")
            break
    reqs = request_records(events)
    if not reqs:
        problems.append("no complete request lifecycle spans "
                        "(name='req', ph 'b'..'e')")
    for r in reqs:
        for k in ("rid", "arrival", "n_generated", "cancelled"):
            if k not in r:
                problems.append(f"request record missing {k!r}: {r}")
                return problems
    return problems


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace-event JSON from --trace")
    ap.add_argument("--check", action="store_true",
                    help="validate instead of report: exit 1 unless the "
                         "trace is loadable and has request spans")
    ap.add_argument("--top", type=int, default=20,
                    help="rows in the TTFT waterfall")
    args = ap.parse_args(argv)
    try:
        doc = load(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    if args.check:
        problems = check(doc)
        if problems:
            for p in problems:
                print(f"FAIL: {p}", file=sys.stderr)
            return 1
        reqs = request_records(doc["traceEvents"])
        print(f"OK: {len(doc['traceEvents'])} events, "
              f"{len(reqs)} request spans")
        return 0
    print_report(doc, top=args.top)
    return 0


if __name__ == "__main__":
    sys.exit(main())

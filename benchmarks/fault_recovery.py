"""Fault injection + graceful degradation (PR 10): the chaos-smoke bench.

Three seeded, fully deterministic arms, each gating a recovery invariant:

* **serve_failover** — a 2-shard frontend replays the same Poisson trace
  clean and under a fault plan (one mid-trace shard crash + a lossy
  status channel). Gates: every admitted request finishes (failover
  requeues in-flight work, deadlines unchanged), the rebuilt replica
  passes ``verify_replicas`` after the anti-entropy resync, and goodput
  degrades gracefully — ``goodput_fault / goodput_clean >=``
  ``MIN_GOODPUT_RATIO``, not a cliff.
* **disk_quarantine** — one tiered engine whose disk tier fails every
  read: after ``quarantine_after`` consecutive I/O errors the tier is
  fenced and the run completes with ZERO uncaught exceptions, degraded to
  the two-tier (host + recompute) semantics.
* **sim_lineage** — a chain job re-run with a mid-run worker crash: the
  lost blocks recompute through the ``JobDAG`` lineage, charged to the
  makespan (``makespan_fault > makespan_clean``), and the replica
  coherence proof inside ``ClusterSim.run`` covers the crashed run too.

    PYTHONPATH=src python -m benchmarks.fault_recovery [--toy]
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from .common import print_table, save_results

BT = 8                  # block_tokens
MAX_NEW = 4
MAX_SEQ = 96
CRASH_T = 5.0           # virtual-clock shard-crash time (mid-trace)
DEADLINE = 60.0         # generous TTFT SLO: clean goodput ~1.0
MIN_GOODPUT_RATIO = 0.5


def _prompts(vocab, n, prefix_tokens=16, seed=0):
    rng = np.random.default_rng(seed)
    n_families = max(n // 4, 1)
    prefixes = [list(rng.integers(0, vocab, prefix_tokens))
                for _ in range(n_families)]
    return [prefixes[i % n_families] + list(rng.integers(0, vocab, 8))
            for i in range(n)]


def _frontend(cfg, params, blk, faults=None):
    from repro.serve import ShardedFrontend
    return ShardedFrontend(cfg, params, 2, max_slots=2, max_seq=MAX_SEQ,
                           capacity_bytes=48 * blk, policy="lerc",
                           block_tokens=BT, prefill_chunk=8,
                           max_queue=64, faults=faults)


def _serve_failover(cfg, params, blk, n_requests) -> dict:
    from repro.faults import BusFault, FaultPlan
    from repro.serve import TracedRequest, latency_stats, play_trace
    from repro.sim import poisson_arrivals

    prompts = _prompts(cfg.vocab, n_requests)
    times = poisson_arrivals(n_requests, rate=1.5, seed=3)
    trace = [TracedRequest(t=t, prompt=p, max_new=MAX_NEW,
                           deadline=DEADLINE)
             for t, p in zip(times, prompts)]
    plan = FaultPlan(
        seed=7,
        shard_crashes=((CRASH_T, 0),),
        bus_faults=(BusFault(channel="status", drop_p=0.2),))

    clean = _frontend(cfg, params, blk)
    stats_clean = latency_stats(play_trace(clean, trace))
    clean.verify_replicas()
    clean.close()

    front = _frontend(cfg, params, blk, faults=plan)
    report = play_trace(front, trace)
    stats = latency_stats(report)
    m = front.metrics()
    # recovery invariants: the crash actually fired, every admitted
    # request still finished, and the rebuilt replica reconverged
    assert m["shard_crashes"] == 1, "scheduled shard crash did not fire"
    unfinished = [r for r in report.requests
                  if not r.cancelled and r.finished_at is None]
    assert not unfinished, \
        f"failover lost {len(unfinished)} admitted requests"
    front.resync_replicas()
    front.verify_replicas()
    front.close()
    ratio = stats["goodput"] / max(stats_clean["goodput"], 1e-9)
    assert ratio >= MIN_GOODPUT_RATIO, \
        f"goodput fell off a cliff under faults: {ratio:.3f}"
    return {
        "arm": "serve_failover",
        "goodput_clean": stats_clean["goodput"],
        "goodput_fault": stats["goodput"],
        "goodput_ratio": round(ratio, 4),
        "ttft_p95_clean": stats_clean["ttft_p95"],
        "ttft_p95_fault": stats["ttft_p95"],
        "shard_crashes": m["shard_crashes"],
        "failover_retries": m["failover_retries"],
        "msg_dropped": m["msg_dropped"],
        "msg_resyncs": m["msg_resyncs"],
        "replicas_ok": True,
    }


def _disk_quarantine(cfg, params, blk, n_families) -> dict:
    from repro.faults import FaultPlan
    from repro.serve import ServeEngine, TieredKVStore

    rng = np.random.default_rng(5)
    prefixes = [list(rng.integers(0, cfg.vocab, 32))
                for _ in range(n_families)]
    suffix = list(rng.integers(0, cfg.vocab, 8))
    store = TieredKVStore(8 * blk, "lerc", block_tokens=BT,
                          host_capacity_bytes=3 * blk,
                          disk_capacity_bytes=64 * blk)
    store.faults = FaultPlan(disk_read_error_p=1.0,
                             quarantine_after=2).injector()
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=MAX_SEQ,
                      store=store, prefill_chunk=8)
    # warm every family (later ones demote earlier ones device->host->
    # disk), then re-reference: every promotion that touches the disk
    # tier fails, and after quarantine_after consecutive errors the tier
    # is fenced — the whole loop must complete without an exception
    for pfx in prefixes:
        eng.submit(pfx + suffix, max_new=MAX_NEW)
        eng.run()
    finished = 0
    for pfx in prefixes:
        req = eng.submit(list(pfx), max_new=MAX_NEW)
        eng.run()
        finished += req.finished_at is not None or req.done
    m = eng.metrics()
    eng.close()
    assert m["disk_quarantines"] == 1, \
        f"disk tier not quarantined: {m['disk_quarantines']}"
    assert m["disk_io_errors"] >= 2
    assert finished == n_families, "degraded engine dropped requests"
    return {
        "arm": "disk_quarantine",
        "disk_io_errors": m["disk_io_errors"],
        "disk_quarantines": m["disk_quarantines"],
        "disk_evictions": m["disk_evictions"],
        "completed": finished,
        "exceptions": 0,
    }


def _chain_dag(n_tasks, block_size):
    from repro.core import BlockMeta, JobDAG, TaskSpec
    dag = JobDAG()
    dag.add_block(BlockMeta("src", block_size, "src", 0))
    prev = "src"
    for i in range(n_tasks):
        out = f"b{i}"
        dag.add_block(BlockMeta(out, block_size, "chain", i))
        dag.add_task(TaskSpec(id=f"t{i}", inputs=(prev,), output=out,
                              job="chain"))
        prev = out
    return dag


def _sim_lineage(n_tasks) -> dict:
    from repro.faults import FaultPlan
    from repro.sim import ClusterSim, HardwareModel

    size = 10 * 2 ** 20
    hw = HardwareModel(cache_bytes=8 * size)

    sim = ClusterSim(1, hw)
    sim.submit(_chain_dag(n_tasks, size))
    clean = sim.run()

    crash_t = clean.makespan / 2
    sim_f = ClusterSim(1, hw,
                       faults=FaultPlan(worker_crashes=((crash_t, 0),)))
    sim_f.submit(_chain_dag(n_tasks, size))
    fault = sim_f.run()        # verify_replicas runs inside
    assert sim_f.worker_crashes_fired == 1
    assert fault.makespan > clean.makespan, \
        "lineage recompute not charged to the makespan"
    return {
        "arm": "sim_lineage",
        "makespan_clean_s": round(clean.makespan, 4),
        "makespan_fault_s": round(fault.makespan, 4),
        "recompute_overhead_s": round(fault.makespan - clean.makespan, 4),
        "worker_crashes": sim_f.worker_crashes_fired,
        "replicas_ok": True,
    }


def main(argv=None, toy: bool = False) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--toy", action="store_true",
                    help="CI scale: fewer requests / shorter chain")
    args = ap.parse_args(argv if argv is not None else [])
    args.toy = args.toy or toy

    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import PrefixStore, ServeEngine

    cfg = configs.get("qwen2_7b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype=jax.numpy.float32)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    probe = ServeEngine(cfg, params, max_slots=1, max_seq=MAX_SEQ,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    blk = probe._block_nbytes()

    n_requests = 12 if args.toy else 24
    rows = [
        _serve_failover(cfg, params, blk, n_requests),
        _disk_quarantine(cfg, params, blk, n_families=3 if args.toy else 5),
        _sim_lineage(n_tasks=4 if args.toy else 8),
    ]
    print_table("Fault recovery: failover / quarantine / lineage "
                "(all recovery gates asserted)",
                rows, sorted({k for r in rows for k in r},
                             key=lambda k: (k != "arm", k)))
    save_results("fault_recovery", rows)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])

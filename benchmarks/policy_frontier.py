"""Extended policy frontier (beyond the paper's three): all 8 policies +
the Belady clairvoyant bound on the paper's §IV workload, at the paper's
cache sweep. Shows where LERC sits between practical policies and OPT.

``sim_wall_s`` is the simulator's own wall-clock — dominated by victim
selection, i.e. the eviction substrate (now EvictionIndex heap pops
instead of a full sort per eviction batch).
"""
from __future__ import annotations

import time

from repro.sim import ClusterSim, HardwareModel, multi_tenant_zip, \
    zip_access_trace

from .common import N_WORKERS, PAPER_HW, print_table, save_results

POLICIES = ["lru", "mru", "fifo", "lfu", "lrc", "sticky", "lerc", "belady"]


def run(policy: str, cache_gb: float, n_jobs=6, n_blocks=60):
    hw = HardwareModel(cache_bytes=int(cache_gb * 2 ** 30) // N_WORKERS,
                       **PAPER_HW)
    sim = ClusterSim(N_WORKERS, hw, policy=policy)
    for dag, _ in multi_tenant_zip(n_jobs=n_jobs, n_blocks=n_blocks,
                                   n_workers=N_WORKERS):
        sim.submit(dag)
    t0 = time.perf_counter()
    sim.run(stages={0})
    res = sim.run(stages={1},
                  belady_trace=zip_access_trace(n_jobs, n_blocks)
                  if policy == "belady" else None)
    wall = time.perf_counter() - t0
    return {
        "policy": policy,
        "cache_gb": cache_gb,
        "makespan_s": round(res.makespan, 2),
        "hit_ratio": round(res.metrics.hit_ratio, 3),
        "effective_hit_ratio": round(res.metrics.effective_hit_ratio, 3),
        "sim_wall_s": round(wall, 2),
    }


def main() -> None:
    rows = []
    for gb in (2.4, 3.6):
        for p in POLICIES:
            rows.append(run(p, gb))
    print_table("Policy frontier (8 policies + Belady bound)", rows,
                ["policy", "cache_gb", "makespan_s", "hit_ratio",
                 "effective_hit_ratio", "sim_wall_s"])
    save_results("policy_frontier", rows)
    for gb in (2.4, 3.6):
        sub = {r["policy"]: r["makespan_s"] for r in rows
               if r["cache_gb"] == gb}
        gap = (sub["lerc"] - sub["belady"]) / max(sub["belady"], 1e-9)
        rel = (f"{-gap:.1%} FASTER than" if gap < 0
               else f"within {gap:.1%} of")
        print(f"cache={gb}GB: LERC {rel} the hit-ratio-optimal Belady "
              f"bound — the clairvoyant policy optimizes the wrong metric "
              f"(the paper's thesis, sharpened)")


if __name__ == "__main__":
    main()

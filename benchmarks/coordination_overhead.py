"""Coordination-protocol overhead from real bus traffic (paper §III-C).

The paper's low-overhead claim: LERC's coordination — peer-profile
broadcasts at job submission plus one eviction report/broadcast per
complete→incomplete peer-group flip — is a small fraction of the
cluster's messaging, and grows gently with cluster size. Since PR 3 the
simulator's workers and the serve tier's shards run their cross-worker
state through ``core.MessageBus``, so these numbers are counted off the
actual protocol messages (and their serialized payload bytes), not
hand-maintained counters.

Two sweeps:

* **sim**: messages + bytes vs ``n_workers`` for lerc vs lrc vs lru on the
  multi-tenant zip workload. LRU ships nothing LERC-specific (DAG-oblivious
  ⇒ no profiles, no reports); LRC ships profiles only; LERC adds the
  eviction protocol — whose cost is bounded by the flip theorem.
* **serve**: messages + bytes vs ``--shards`` for the sharded frontend on
  a shared-prefix workload (every store event crosses the bus; the LERC
  channel is the profile + eviction-report fraction).

Usage:
    PYTHONPATH=src python -m benchmarks.coordination_overhead [--toy]
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from benchmarks.common import print_table, save_results

from repro.sim import ClusterSim, HardwareModel, multi_tenant_zip


def sim_overhead(n_workers_list: List[int], n_jobs: int, n_blocks: int,
                 cache_gb: float) -> List[Dict]:
    rows = []
    for policy in ("lru", "lrc", "lerc"):
        for n_workers in n_workers_list:
            hw = HardwareModel(
                cache_bytes=int(cache_gb * 2 ** 30) // n_workers,
                disk_bw=25e6)
            sim = ClusterSim(n_workers, hw, policy=policy)
            for dag, _ in multi_tenant_zip(n_jobs=n_jobs, n_blocks=n_blocks,
                                           n_workers=n_workers):
                sim.submit(dag)
            sim.run(stages={0})
            res = sim.run(stages={1})
            s = res.messages
            lerc_msgs = (s.peer_profile_broadcasts * n_workers
                         + s.eviction_reports
                         + s.eviction_broadcasts * n_workers)
            rows.append({
                "tier": "sim", "policy": policy, "n_workers": n_workers,
                "evictions": res.metrics.evictions,
                "profiles": s.peer_profile_broadcasts,
                "evict_reports": s.eviction_reports,
                "evict_bcasts": s.eviction_broadcasts,
                "msgs_total": s.point_to_point,
                "msgs_lerc": lerc_msgs,
                "bytes_total": s.payload_bytes,
                "bytes_lerc": s.lerc_bytes,
                "lerc_byte_frac": round(
                    s.lerc_bytes / max(s.payload_bytes, 1), 4),
            })
    return rows


def serve_overhead(shards_list: List[int], n_requests: int,
                   cache_blocks: int) -> List[Dict]:
    import jax
    import numpy as np

    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import PrefixStore, ServeEngine, ShardedFrontend

    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    bt = 8
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=bt),
                        pool_blocks=1)
    cap = probe._block_nbytes() * cache_blocks

    rng = np.random.default_rng(0)
    n_families = max(n_requests // 4, 1)
    prefixes = [list(rng.integers(0, cfg.vocab, 24))
                for _ in range(n_families)]
    reqs = [prefixes[i % n_families] + list(rng.integers(0, cfg.vocab, 8))
            for i in range(n_requests)]

    rows = []
    for policy in ("lru", "lerc"):
        for n_shards in shards_list:
            fe = ShardedFrontend(cfg, params, n_shards, max_slots=2,
                                 max_seq=64,
                                 capacity_bytes=max(cap // n_shards, 1),
                                 policy=policy, block_tokens=bt)
            for r in reqs:
                fe.submit(r, max_new=4)
            fe.run()
            fe.verify_replicas()
            s = fe.bus.stats
            rows.append({
                "tier": "serve", "policy": policy, "n_workers": n_shards,
                "evictions": int(fe.metrics()["evictions"]),
                "profiles": s.peer_profile_broadcasts,
                "evict_reports": s.eviction_reports,
                "evict_bcasts": s.eviction_broadcasts,
                "msgs_total": s.point_to_point,
                "msgs_lerc": (s.peer_profile_broadcasts * n_shards
                              + s.eviction_reports
                              + s.eviction_broadcasts * n_shards),
                "bytes_total": s.payload_bytes,
                "bytes_lerc": s.lerc_bytes,
                "lerc_byte_frac": round(
                    s.lerc_bytes / max(s.payload_bytes, 1), 4),
            })
    return rows


COLS = ["tier", "policy", "n_workers", "evictions", "profiles",
        "evict_reports", "evict_bcasts", "msgs_total", "msgs_lerc",
        "bytes_total", "bytes_lerc", "lerc_byte_frac"]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--toy", action="store_true",
                    help="CI scale: tiny cluster + few requests")
    args = ap.parse_args(argv)

    if args.toy:
        rows = sim_overhead([2, 4], n_jobs=2, n_blocks=10, cache_gb=0.1)
        rows += serve_overhead([1, 2], n_requests=6, cache_blocks=8)
    else:
        rows = sim_overhead([5, 10, 20], n_jobs=4, n_blocks=40,
                            cache_gb=1.0)
        rows += serve_overhead([1, 2, 4], n_requests=16, cache_blocks=10)

    print_table("coordination overhead (messages + bytes, real traffic)",
                rows, COLS)
    save_results("coordination_overhead", rows)

    # the paper's claim, checked on the way out: LERC's eviction protocol
    # sends at most one report+broadcast per completeness flip, so its
    # traffic stays a small fraction of the legacy status channel
    for r in rows:
        if r["policy"] == "lerc":
            assert r["evict_bcasts"] == r["evict_reports"]
            assert r["evict_bcasts"] <= r["evictions"]
        if r["policy"] == "lru" and r["tier"] == "sim":
            # a DAG-oblivious sim cluster deploys no LERC protocol at all;
            # serve shards currently run it regardless of store policy
            # (ROADMAP open follow-up), so their lru rows are not checked
            assert r["bytes_lerc"] == 0


if __name__ == "__main__":
    main()

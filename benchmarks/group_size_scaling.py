"""Beyond-paper study: how the LERC advantage scales with peer-group size.

The paper evaluates only zip (k=2); §II-C names join/coalesce too.
Going-in hypothesis: the gap WIDENS with k (a peer-oblivious policy keeps
all k inputs with geometrically falling probability). Measured outcome:
HALF-confirmed — LRU's effective-hit ratio does collapse to ~0 at every k
(the mechanism), but at a FIXED byte budget LERC's own effective ratio
also falls with k (a complete group costs k blocks, so fewer groups are
packable), so the makespan advantage PEAKS at small k and narrows as k
grows. Lesson: the all-or-nothing property gets harder for *everyone* to
exploit as groups widen; LERC's edge is largest where complete groups are
affordable. Recorded as a refuted-and-refined hypothesis in
EXPERIMENTS.md.
"""
from __future__ import annotations

import time

from repro.sim import ClusterSim, HardwareModel, coalesce_job

from .common import N_WORKERS, PAPER_HW, print_table, save_results

POLICIES = ["lru", "lrc", "lerc"]
TOTAL_BLOCKS = 960                    # constant bytes across k; several
BLOCK_MB = 4                          # task waves per worker slot


def run(policy: str, group_size: int, cache_frac: float = 0.5):
    n_groups = TOTAL_BLOCKS // group_size
    hw = HardwareModel(
        cache_bytes=int(cache_frac * TOTAL_BLOCKS * BLOCK_MB * 2 ** 20)
        // N_WORKERS, **PAPER_HW)
    sim = ClusterSim(N_WORKERS, hw, policy=policy)
    for t in range(3):                # 3 tenants
        dag, _ = coalesce_job(f"j{t}", n_groups // 3, group_size,
                              BLOCK_MB * 2 ** 20, n_workers=N_WORKERS)
        sim.submit(dag)
    t0 = time.perf_counter()
    sim.run(stages={0})
    res = sim.run(stages={1})
    wall = time.perf_counter() - t0
    return {
        "policy": policy, "group_size": group_size,
        "makespan_s": round(res.makespan, 2),
        "hit_ratio": round(res.metrics.hit_ratio, 3),
        "effective_hit_ratio": round(res.metrics.effective_hit_ratio, 3),
        "sim_wall_s": round(wall, 2),
    }


def main() -> None:
    rows = []
    for k in (2, 4, 8):
        for p in POLICIES:
            rows.append(run(p, k))
    print_table("Peer-group size scaling (coalesce-k)", rows,
                ["policy", "group_size", "makespan_s", "hit_ratio",
                 "effective_hit_ratio", "sim_wall_s"])
    save_results("group_size_scaling", rows)
    print()
    for k in (2, 4, 8):
        sub = {r["policy"]: r for r in rows if r["group_size"] == k}
        gap = 1 - sub["lerc"]["makespan_s"] / sub["lru"]["makespan_s"]
        print(f"k={k}: LERC vs LRU makespan {gap:+.1%} "
              f"(effective-hit {sub['lerc']['effective_hit_ratio']:.2f} "
              f"vs {sub['lru']['effective_hit_ratio']:.2f})")


if __name__ == "__main__":
    main()

"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.sim import ClusterSim, HardwareModel, multi_tenant_zip

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# Calibration note (EXPERIMENTS.md §Paper-repro): the simulator models the
# paper's fleet of 20 m4.large nodes. disk_bw reflects EBS with direct I/O
# (paper §IV disables the page cache); fetches of a task's peers proceed in
# parallel, so one cold peer hides a warm one (the all-or-nothing
# bottleneck). Absolute seconds are not the reproduction target — the
# policy *ratios* are.
PAPER_HW = dict(disk_bw=25e6)
N_WORKERS = 20
CACHE_SIZES_GB = [2.0, 4.0, 5.3, 6.6, 8.0]
POLICIES = ["lru", "lrc", "lerc"]


def run_multi_tenant(policy: str, cache_gb: float, n_jobs: int = 10,
                     n_blocks: int = 100, extra_policies_kwargs=None,
                     **hw_kwargs) -> Dict:
    """Paper §IV experiment: ingest phase (unmeasured) then the timed zip
    phase of 10 tenant jobs."""
    hw = HardwareModel(cache_bytes=int(cache_gb * 2 ** 30) // N_WORKERS,
                       **{**PAPER_HW, **hw_kwargs})
    sim = ClusterSim(N_WORKERS, hw, policy=policy,
                     policy_kwargs=extra_policies_kwargs or {})
    for dag, _outs in multi_tenant_zip(n_jobs=n_jobs, n_blocks=n_blocks,
                                       n_workers=N_WORKERS):
        sim.submit(dag)
    sim.run(stages={0})
    res = sim.run(stages={1})
    return {
        "policy": policy,
        "cache_gb": cache_gb,
        "makespan_s": round(res.makespan, 3),
        "hit_ratio": round(res.metrics.hit_ratio, 4),
        "effective_hit_ratio": round(res.metrics.effective_hit_ratio, 4),
        "evictions": res.metrics.evictions,
        "eviction_broadcasts": res.messages.eviction_broadcasts,
        "disk_bytes_read": res.metrics.disk_bytes_read,
    }


def save_results(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    return path


def print_table(title: str, rows: List[Dict], cols: List[str]) -> None:
    print(f"\n== {title} ==")
    widths = {c: max(len(c), max((len(str(r.get(c, ''))) for r in rows),
                                 default=0)) for c in cols}
    print(" | ".join(c.ljust(widths[c]) for c in cols))
    print("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        print(" | ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))

"""Beyond-paper benchmark: LERC on the serving prefix cache.

Zipf-shared prefix workload against the REAL engine (smoke model): N
request families with shared prefixes, constrained KV budget. Reports,
per eviction policy, the effective chain hit ratio and the fraction of
prefill tokens actually skipped — the serving analogue of paper Fig. 7.

Since the serve path now runs on the shared core substrate, every
``core`` policy is available here via ``make_policy`` — the sweep
includes LFU and the paper's Sticky strawman alongside the seed trio.
"""
from __future__ import annotations

import numpy as np

from .common import print_table, save_results

POLICIES = ["lru", "lfu", "lrc", "sticky", "lerc"]


def run_policy(policy: str, *, n_requests: int = 24, n_families: int = 6,
               cache_bytes: int = 0, seed: int = 0):
    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import PrefixStore, ServeEngine

    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    rng = np.random.default_rng(seed)
    # Zipf popularity over families
    fam_p = 1.0 / np.arange(1, n_families + 1)
    fam_p /= fam_p.sum()
    prefixes = [list(rng.integers(0, cfg.vocab, 24))
                for _ in range(n_families)]
    store = PrefixStore(capacity_bytes=cache_bytes, policy=policy,
                        block_tokens=8)
    eng = ServeEngine(cfg, params, max_slots=3, max_seq=64, store=store)
    for _ in range(n_requests):
        fam = rng.choice(n_families, p=fam_p)
        eng.submit(prefixes[fam] + list(rng.integers(0, cfg.vocab, 8)),
                   max_new=4)
    eng.run()
    m = eng.metrics()
    return {
        "policy": policy,
        "hit_ratio": round(m["hit_ratio"], 3),
        "effective_hit_ratio": round(m["effective_hit_ratio"], 3),
        "prefill_saved_frac": round(m["prefill_saved_frac"], 3),
        "evictions": m["evictions"],
    }


def main() -> None:
    # budget ~ half of the working set -> pressure
    import jax
    from repro import configs
    from repro.models import init_params, model_spec
    from repro.serve import ServeEngine, PrefixStore
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    probe = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=8),
                        pool_blocks=1)
    blk = probe._block_nbytes()
    budget = blk * 12               # ~12 resident blocks
    rows = [run_policy(p, cache_bytes=budget) for p in POLICIES]
    print_table("Prefix cache (beyond paper): policy comparison", rows,
                ["policy", "hit_ratio", "effective_hit_ratio",
                 "prefill_saved_frac", "evictions"])
    save_results("prefix_cache", rows)
    lerc = next(r for r in rows if r["policy"] == "lerc")
    lru = next(r for r in rows if r["policy"] == "lru")
    print(f"\nLERC prefill saved {lerc['prefill_saved_frac']:.1%} vs "
          f"LRU {lru['prefill_saved_frac']:.1%}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Roofline extraction per (arch × shape × mesh) — §Roofline method.

XLA counts a ``lax.scan`` body ONCE regardless of trip count (verified
empirically; see DESIGN.md §8), so per-cell totals are recovered by a
two-point fit over reduced-depth compiles:

    unit  = cost(2 pattern-units) - cost(1 pattern-unit)
    tail  = cost(1 unit + tail)   - cost(1 unit)        [if a tail exists]
    total = cost(1 unit) + (n_rep - 1) * unit + tail

applied identically to HLO FLOPs, bytes-accessed and parsed collective
wire bytes. Train cells are fitted at microbatches=1 (the accumulation
scan would otherwise hide k-1 of the k microbatches) and scaled by k
where k is the production microbatch count; memory comes from the full
production compile (the dry-run artifact).

Terms (TPU v5e): compute = FLOPs / (chips·197 TFLOP/s bf16);
memory = bytes / (chips·819 GB/s); collective = per-chip wire bytes /
(50 GB/s ICI link). MODEL_FLOPS is the analytic useful-work count
(matmul params × tokens × 2 [×3 for bwd] + exact causal attention-score
FLOPs); the MODEL/HLO ratio flags remat and upper-triangle waste.
"""
import argparse
import json
import sys
from typing import Dict, Optional

import numpy as np

from repro import configs
from repro.models.common import ModelConfig
from repro.models.lm import unit_pattern
from repro.models.recurrent import _LORA_DIM, rwkv_heads

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
CHIPS = {False: 256, True: 512}

RESULTS = os.path.join(os.path.dirname(__file__), "results")


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def _per_layer_matmul_params(cfg: ModelConfig, kind: str) -> float:
    d, H, KV, Dh, f = (cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.d_head,
                       cfg.d_ff)
    nc = 2 if cfg.act in ("swiglu", "geglu") else 1
    attn = d * H * Dh + 2 * d * KV * Dh + H * Dh * d
    if kind in ("G", "L"):
        ff = cfg.dense_d_ff or f if (kind == "G" and cfg.n_experts) else f
        return attn + nc * d * ff + ff * d
    if kind == "M":
        active = cfg.top_k + cfg.n_shared_experts
        return attn + active * (nc * d * f + f * d) + d * cfg.n_experts
    if kind == "R":
        W = cfg.lru_width
        rec = 2 * d * W + W * d + cfg.conv_width * W + 2 * W * (W // 16)
        return rec + nc * d * f + f * d
    if kind == "W":
        Hh, N = rwkv_heads(cfg)
        tm = 4 * d * Hh * N + d * _LORA_DIM + _LORA_DIM * Hh * N \
            + Hh * N * d
        cm = d * f + f * d + d * d
        return tm + cm
    raise ValueError(kind)


def _attn_score_flops(cfg: ModelConfig, kind: str, seq: int,
                      mode: str, kv_len: int) -> float:
    """Exact useful attention-score FLOPs per sequence (qk^T + pv)."""
    if kind in ("R", "W"):
        # linear recurrences: state ops, counted per token
        if kind == "R":
            return 4.0 * cfg.lru_width * (seq if mode != "decode" else 1)
        Hh, N = rwkv_heads(cfg)
        return 4.0 * Hh * N * N * (seq if mode != "decode" else 1)
    H, Dh = cfg.n_heads, cfg.d_head
    if mode == "decode":
        eff = min(cfg.window, kv_len) if (kind == "L" and cfg.window) \
            else kv_len
        return 4.0 * H * Dh * eff
    if kind == "L" and cfg.window:
        w = min(cfg.window, seq)
        avg = w / 2 + (seq - w) * w / seq if seq > w else seq / 2
        return 4.0 * H * Dh * seq * avg
    return 4.0 * H * Dh * seq * (seq + 1) / 2


def model_flops(cfg: ModelConfig, shape) -> float:
    pat, n_rep, tail = unit_pattern(cfg)
    kinds = list(pat) * n_rep + list(tail)
    seq = shape.seq_len
    B = shape.global_batch
    mode = shape.kind
    tokens = B * (1 if mode == "decode" else seq)
    mm = sum(_per_layer_matmul_params(cfg, k) for k in kinds)
    mm += cfg.d_model * cfg.vocab                      # unembed
    if cfg.family == "encdec":
        enc_mm = cfg.n_encoder_layers * _per_layer_matmul_params(cfg, "G")
        mm += enc_mm * (cfg.frontend_len / max(seq, 1))  # enc runs on frames
    total = 2.0 * mm * tokens
    total += B * sum(_attn_score_flops(cfg, k, seq, mode, seq)
                     for k in kinds)
    if mode == "train":
        total *= 3.0                                   # fwd + bwd
    return total


# ---------------------------------------------------------------------------
# Depth-delta extraction
# ---------------------------------------------------------------------------


def _reduced(cfg: ModelConfig, n_units: int, with_tail: bool):
    pat, n_rep, tail = unit_pattern(cfg)
    n_layers = n_units * len(pat) + (len(tail) if with_tail else 0)
    kw = dict(n_layers=n_layers)
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = n_units
    return cfg.replace(**kw)


def extract_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                 microbatches: int = 8, production: Optional[Dict] = None,
                 exact_causal: Optional[bool] = None,
                 seq_shard: bool = True, cost_mb: int = 1,
                 moments_dtype: str = "float32") -> Dict:
    """``cost_mb=1`` (default) fits the per-step cost with the whole batch
    in one pass — correct FLOPs/bytes, but FSDP weight-gather collectives
    that repeat per microbatch are counted once. ``cost_mb=k`` unrolls the
    k-microbatch accumulation loop for production-exact collectives
    (§Perf hillclimb C uses this)."""
    from repro.launch.dryrun import run_cell
    cfg = configs.get(arch)
    if exact_causal is not None:
        cfg = cfg.replace(exact_causal=exact_causal)
    shape = configs.SHAPES[shape_name]
    pat, n_rep, tail = unit_pattern(cfg)
    is_train = shape.kind == "train"
    mb_cost = cost_mb if is_train else 1
    mb_prod = microbatches if is_train else 1

    def costs(n_units, with_tail=False):
        # fully unroll the layer scan (unroll = trip count -> no while
        # loop) AND the attention inner KV scans: XLA's cost analysis
        # counts loop bodies once, so only unrolled code is countable
        import repro.models.attention as A
        A.UNROLL_INNER = True
        try:
            n_layers_units = n_units  # scan length == unroll
            r = run_cell(arch, shape_name, multi_pod=multi_pod,
                         cfg_override=_reduced(cfg, n_units, with_tail),
                         microbatches=mb_cost, seq_shard=seq_shard,
                         unroll=max(n_layers_units, 1),
                         mb_unroll=mb_cost > 1,
                         moments_dtype=moments_dtype)
        finally:
            A.UNROLL_INNER = False
        return np.array([r["cost"]["flops"],
                         r["cost"]["bytes_accessed"],
                         r["collectives"]["total_bytes"]])

    c1 = costs(1)
    c2 = costs(2)
    unit = c2 - c1
    tail_cost = (costs(1, with_tail=True) - c1) if tail else 0.0
    # the mb=1 fit already pushes the full global batch through one pass,
    # so no microbatch scaling is needed — mb only affects peak memory
    total = c1 + (n_rep - 1) * unit + tail_cost
    # memory: production compile (the dry-run artifact)
    prod = production or run_cell(arch, shape_name, multi_pod=multi_pod,
                                  microbatches=mb_prod,
                                  seq_shard=seq_shard)

    chips = CHIPS[multi_pod]
    # cost_analysis reports PER-DEVICE quantities for SPMD modules
    # (verified: a 4-way-sharded matmul reports 2MNK/4) — so the terms
    # divide by per-chip peaks only; chips enter via the global ratio.
    flops, bytes_acc, coll = (float(x) for x in total)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "hlo_flops_per_chip": flops, "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / (flops * chips) if flops else 0.0,
        "step_time_bound_s": max(t_compute, t_memory, t_coll),
        "mfu_bound": (mf / chips / PEAK_FLOPS)
        / max(t_compute, t_memory, t_coll, 1e-12),
        "peak_bytes_per_dev": prod["memory"]["peak_bytes"],
        "hbm_frac": prod["hbm_frac"],
    }


def print_cached(path: str) -> bool:
    if not os.path.exists(path):
        return False
    with open(path) as f:
        rows = json.load(f)
    print(f"(cached {path}; re-extract with --cells all)")
    for r in rows:
        if "error" in r:
            print(f"{r['arch']:28s} {r['shape']:12s} ERROR {r['error']}")
            continue
        print(f"{r['arch']:28s} {r['shape']:12s} dom={r['dominant']:10s} "
              f"tc={r['t_compute_s']*1e3:8.2f}ms "
              f"tm={r['t_memory_s']*1e3:8.2f}ms "
              f"tx={r['t_collective_s']*1e3:8.2f}ms "
              f"useful={r['useful_ratio']:.2f} "
              f"hbm={100*r['hbm_frac']:5.1f}%")
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=None,
                    help="'all' or comma list arch:shape; default: print "
                         "the cached table (or a 3-cell sample)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=os.path.join(RESULTS,
                                                   "roofline.json"))
    args = ap.parse_args(argv)
    if args.cells is None:
        if print_cached(args.json):
            return 0
        cells = [("qwen2_7b", "train_4k"), ("rwkv6_3b", "prefill_32k"),
                 ("gemma2_27b", "decode_32k")]
        args.json = os.path.join(RESULTS, "roofline_sample.json")
    elif args.cells == "all":
        cells = configs.cells()
    else:
        cells = [tuple(c.split(":")) for c in args.cells.split(",")]
    rows = []
    for arch, shape in cells:
        try:
            r = extract_cell(arch, shape, multi_pod=args.multi_pod)
            rows.append(r)
            print(f"{arch:28s} {shape:12s} dom={r['dominant']:10s} "
                  f"tc={r['t_compute_s']*1e3:8.2f}ms "
                  f"tm={r['t_memory_s']*1e3:8.2f}ms "
                  f"tx={r['t_collective_s']*1e3:8.2f}ms "
                  f"useful={r['useful_ratio']:.2f}", flush=True)
        except Exception as e:
            print(f"[FAIL] {arch} {shape}: {type(e).__name__}: {e}",
                  flush=True)
            rows.append({"arch": arch, "shape": shape, "error": str(e)})
    os.makedirs(RESULTS, exist_ok=True)
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Uniform model API over every assigned architecture.

One entry point per lifecycle stage, dispatching on ``cfg.family``:

* ``model_spec(cfg)``                 — ParamSpec tree
* ``forward(cfg, params, batch)``     — logits for training / prefill
* ``loss_fn(cfg, params, batch)``     — scalar LM loss (next-token CE)
* ``decode_cache_shapes`` / ``init_decode_cache`` / ``decode_step``
* ``batch_shapes(cfg, batch, seq)``   — abstract input shapes (dry-run)

Batch dict keys: ``tokens``/``targets`` always; ``patches`` for vlm
(precomputed patch embeddings, frontend stub); ``frames`` for audio
(precomputed frame embeddings, frontend stub).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import encdec as ED
from . import lm as LM
from .common import ModelConfig


def model_spec(cfg: ModelConfig) -> Dict:
    if cfg.family == "encdec":
        return ED.encdec_spec(cfg)
    return LM.lm_spec(cfg)


def forward(cfg: ModelConfig, params, batch: Dict, *, mesh_ctx=None,
            unroll: int = 1, last_logit_only: bool = False):
    if cfg.family == "encdec":
        return ED.encdec_forward(cfg, params, batch["tokens"],
                                 batch["frames"], mesh_ctx=mesh_ctx,
                                 unroll=unroll,
                                 last_logit_only=last_logit_only)
    return LM.lm_forward(cfg, params, batch["tokens"], mesh_ctx=mesh_ctx,
                         patches=batch.get("patches"), unroll=unroll,
                         last_logit_only=last_logit_only)


def loss_fn(cfg: ModelConfig, params, batch: Dict, *, mesh_ctx=None,
            unroll: int = 1):
    logits = forward(cfg, params, batch, mesh_ctx=mesh_ctx, unroll=unroll)
    targets = batch["targets"]
    if cfg.frontend == "patch_embed" and logits.shape[1] != targets.shape[1]:
        # drop the image-prefix positions: only text positions carry loss
        logits = logits[:, -targets.shape[1]:]
    return LM.lm_loss(cfg, logits, targets, batch.get("mask"))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                        enc_len: int = 0) -> Dict:
    if cfg.family == "encdec":
        return ED.encdec_cache_shapes(cfg, batch, max_seq,
                                      enc_len or cfg.frontend_len)
    return LM.cache_shapes(cfg, batch, max_seq)


def cache_leaf_dtype(cfg: ModelConfig, name: str):
    """Recurrent state ('S', 'h') is kept fp32 for long-horizon fidelity;
    KV and shift buffers store in model dtype."""
    return jnp.float32 if name in ("S", "h") else cfg.dtype


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int,
                      enc_len: int = 0):
    shapes = decode_cache_shapes(cfg, batch, max_seq, enc_len)

    def walk(tree, name=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        return jnp.zeros(tree, cache_leaf_dtype(cfg, name))

    return walk(shapes)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos, *,
                mesh_ctx=None, unroll: int = 1, seq_lens=None,
                paged_tables=None, kv_shard=None):
    """(logits (B,1,V), new_cache). tokens: (B,S) — S=1 for plain decode,
    S>1 for chunked prefill (per-row start ``pos``, real lengths
    ``seq_lens``). pos: scalar absolute position or (B,) per-slot.
    ``paged_tables`` (B, NW): ``cache`` is the KV pool pytree and decode
    runs straight out of the pool rows each row's block table names.
    ``kv_shard`` (``sharding.KVShardCtx``): the pool leaves are sharded
    on their KV-head dim and attention runs per-device under shard_map."""
    if cfg.family == "encdec":
        if seq_lens is not None or tokens.shape[1] != 1 \
                or paged_tables is not None or kv_shard is not None:
            raise NotImplementedError(
                "chunked/paged decode is decoder-LM only (encdec is S=1)")
        return ED.encdec_decode_step(cfg, params, cache, tokens, pos,
                                     mesh_ctx=mesh_ctx, unroll=unroll)
    return LM.lm_decode_step(cfg, params, cache, tokens, pos,
                             mesh_ctx=mesh_ctx, unroll=unroll,
                             seq_lens=seq_lens, paged_tables=paged_tables,
                             kv_shard=kv_shard)


# ---------------------------------------------------------------------------
# Abstract input shapes (dry-run / input_specs)
# ---------------------------------------------------------------------------


def batch_shapes(cfg: ModelConfig, global_batch: int, seq_len: int
                 ) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """{name: (shape, dtype)} for one *training* batch."""
    out: Dict[str, Tuple[Tuple[int, ...], Any]] = {
        "tokens": ((global_batch, seq_len), jnp.int32),
        "targets": ((global_batch, seq_len), jnp.int32),
    }
    if cfg.frontend == "patch_embed":
        out["patches"] = ((global_batch, cfg.frontend_len, cfg.frontend_dim),
                          cfg.dtype)
    elif cfg.frontend == "audio_frames":
        out["frames"] = ((global_batch, cfg.frontend_len, cfg.d_model),
                         cfg.dtype)
    return out


def make_dummy_batch(cfg: ModelConfig, global_batch: int, seq_len: int,
                     rng: Optional[jax.Array] = None) -> Dict:
    """Concrete random batch for smoke tests / examples."""
    rng = rng if rng is not None else jax.random.key(0)
    ks = jax.random.split(rng, 4)
    batch: Dict[str, Any] = {
        "tokens": jax.random.randint(ks[0], (global_batch, seq_len), 0,
                                     cfg.vocab, jnp.int32),
        "targets": jax.random.randint(ks[1], (global_batch, seq_len), 0,
                                      cfg.vocab, jnp.int32),
    }
    if cfg.frontend == "patch_embed":
        batch["patches"] = jax.random.normal(
            ks[2], (global_batch, cfg.frontend_len, cfg.frontend_dim),
            jnp.float32).astype(cfg.dtype)
    elif cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(
            ks[2], (global_batch, cfg.frontend_len, cfg.d_model),
            jnp.float32).astype(cfg.dtype)
    return batch

"""Model configuration and the parameter-spec system.

Every parameter is declared once as a ``ParamSpec`` carrying its shape and
*logical axis names*. One spec tree serves four consumers:

* ``init_params``          — deterministic parameter initialization,
* ``abstract_params``      — ShapeDtypeStructs for the AOT dry-run (no
                             allocation),
* ``repro.sharding.rules`` — logical axes → mesh ``PartitionSpec``,
* ``repro.train.checkpoint`` — stable names for sharded save/restore.
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0             # 0 -> = n_heads (MHA)
    d_head: int = 128
    # --- attention options -------------------------------------------------
    qkv_bias: bool = False          # qwen-family
    window: Optional[int] = None    # sliding-window size for local layers
    layer_pattern: str = "G"        # repeating pattern: G=global attn,
                                    # L=local attn, R=recurrent(RG-LRU),
                                    # W=rwkv6 block
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    rope_theta: float = 10_000.0
    attn_impl: str = "auto"         # auto | xla | chunked
    attn_q_chunk: int = 2048        # chunked-attention tile sizes
    attn_kv_chunk: int = 2048
    exact_causal: bool = True       # prune upper-triangle chunks (§Perf)
    decode_kernel: str = "auto"     # decode-attention backend: "flash"
                                    # (Pallas flash-decoding / paged kernel,
                                    # interpret mode off-TPU), "xla" (dense
                                    # masked sdpa), "auto" (flash on TPU)
    # --- MLP / MoE ----------------------------------------------------------
    act: str = "swiglu"             # swiglu | geglu | gelu
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    dense_d_ff: int = 0             # d_ff of the dense ("G") layers in a
                                    # mixed dense/MoE pattern (llama4); 0 -> d_ff
    # --- recurrent (RG-LRU / RWKV6) ------------------------------------------
    rnn_width: int = 0              # RG-LRU lru width (0 -> d_model)
    conv_width: int = 4             # temporal-conv window in recurrent block
    # --- encoder-decoder / frontends -----------------------------------------
    n_encoder_layers: int = 0
    frontend: Optional[str] = None  # "audio_frames" | "patch_embed" (stubs)
    frontend_len: int = 0           # frames / patches provided by the stub
    frontend_dim: int = 0           # stub embedding dim (pre-projection)
    # --- misc -----------------------------------------------------------------
    tie_embeddings: bool = True
    embed_scale: bool = False       # gemma family: h *= sqrt(d_model)
    norm: str = "rmsnorm"
    post_norms: bool = False        # gemma2 sandwich norms
    max_seq_len: int = 8192         # positional table size where learned
    dtype: Any = jnp.bfloat16

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def lru_width(self) -> int:
        return self.rnn_width or self.d_model

    def pattern_layers(self) -> Tuple[str, ...]:
        """Per-layer kind for all n_layers, repeating ``layer_pattern``."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names (len == ndim)
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: Any = None                 # None -> model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def p(shape, axes, init="normal", scale=0.02, dtype=None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree, prefix=()):
    """Yield (path_tuple, leaf) over a nested-dict spec/param tree."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from tree_paths(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def init_params(rng: jax.Array, spec_tree, dtype=jnp.bfloat16):
    """Deterministic init: each leaf's key is folded from its path hash, so
    adding/removing parameters never reshuffles the others."""

    def init_leaf(path, s: ParamSpec):
        d = s.dtype or dtype
        if s.init == "zeros":
            return jnp.zeros(s.shape, d)
        if s.init == "ones":
            return jnp.ones(s.shape, d)
        # crc32, not hash(): stable across processes (PYTHONHASHSEED)
        key = jax.random.fold_in(
            rng, zlib.crc32("/".join(path).encode()) % (2 ** 31))
        if s.init == "scaled":          # fan-in scaled
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            return (jax.random.normal(key, s.shape, jnp.float32)
                    * (1.0 / np.sqrt(fan_in))).astype(d)
        return (jax.random.normal(key, s.shape, jnp.float32) * s.scale).astype(d)

    def walk(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: walk(v, prefix + (k,)) for k, v in tree.items()}
        return init_leaf(prefix, tree)

    return walk(spec_tree)


def abstract_params(spec_tree, dtype=jnp.bfloat16, sharding_fn=None):
    """ShapeDtypeStruct tree (for .lower() AOT compilation). If
    ``sharding_fn(path, spec) -> Sharding`` is given, attach shardings."""

    def walk(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: walk(v, prefix + (k,)) for k, v in tree.items()}
        s: ParamSpec = tree
        sh = sharding_fn(prefix, s) if sharding_fn else None
        return jax.ShapeDtypeStruct(s.shape, s.dtype or dtype, sharding=sh)

    return walk(spec_tree)


def param_count(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in tree_paths(spec_tree))

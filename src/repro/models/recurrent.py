"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma) and RWKV6
(Finch). Both are linear recurrences, implemented so that

* training-shape FLOPs live in batched einsums *outside* any sequential
  loop (XLA's cost model counts loop bodies once — see DESIGN.md §8), and
* decode is a cheap O(1)-state single-step update.

RWKV6 uses the chunked linear-attention form with per-channel decays; the
per-chunk exponent shift keeps everything in fp32 range (log-decay is
clamped to [-5, -1e-6] and chunks are 16 tokens).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, p

# ---------------------------------------------------------------------------
# RG-LRU  (Griffin, arXiv:2402.19427, adapted per RecurrentGemma)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0
_NB = 16  # block-diagonal gate blocks (recurrentgemma: per-head)


def rglru_block_spec(cfg: ModelConfig) -> Dict:
    d, W = cfg.d_model, cfg.lru_width
    bs = W // _NB
    return {
        "w_x": p((d, W), ("embed", "rnn"), init="scaled"),
        "w_y": p((d, W), ("embed", "rnn"), init="scaled"),
        "conv_w": p((cfg.conv_width, W), (None, "rnn"), init="scaled"),
        "conv_b": p((W,), ("rnn",), init="zeros"),
        "gate_a": p((_NB, bs, bs), ("rnn_blocks", None, None), init="scaled"),
        "gate_x": p((_NB, bs, bs), ("rnn_blocks", None, None), init="scaled"),
        "lam": p((W,), ("rnn",), init="normal", scale=0.5),
        "w_out": p((W, d), ("rnn", "embed"), init="scaled"),
    }


def _blockdiag(x, w):
    """x: (..., W) @ block-diagonal w: (NB, bs, bs) -> (..., W)."""
    shape = x.shape
    xb = x.reshape(shape[:-1] + (_NB, shape[-1] // _NB))
    yb = jnp.einsum("...nb,nbc->...nc", xb, w)
    return yb.reshape(shape)


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv over time. x: (B,S,W); w: (K,W).
    ``state``: (B,K-1,W) trailing context for decode; returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[K - 1 - i] for i in range(K))
    return y + b, xp[:, -(K - 1):, :]


def _rglru_coeffs(params, x):
    """x: (B,S,W) fp32 -> (log_a, b_in) of the recurrence
    h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t)."""
    r = jax.nn.sigmoid(_blockdiag(x, params["gate_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(_blockdiag(x, params["gate_x"].astype(jnp.float32)))
    # a = sigmoid(lam)^(c*r)  ->  log a = -c * r * softplus(-lam)
    lam = params["lam"].astype(jnp.float32) + 2.0   # bias toward slow decay
    log_a = -_RGLRU_C * r * jax.nn.softplus(-lam)
    b_in = jnp.sqrt(-jnp.expm1(2.0 * log_a)) * (i * x)
    return log_a, b_in


def rglru_scan(params, x):
    """Training/prefill path. x: (B,S,W) -> (B,S,W); returns (y, h_last)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    log_a, b_in = _rglru_coeffs(params, x)
    a = jnp.exp(log_a)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_in), axis=1)
    return h.astype(dt), h[:, -1, :]


def rglru_step(params, x, h_prev):
    """Decode: x (B,1,W), h_prev (B,W) -> (y (B,1,W), h (B,W))."""
    xf = x.astype(jnp.float32)
    log_a, b_in = _rglru_coeffs(params, xf)
    a = jnp.exp(log_a)
    h = a[:, 0] * h_prev.astype(jnp.float32) + b_in[:, 0]
    return h[:, None, :].astype(x.dtype), h


def rglru_block(cfg: ModelConfig, params, x, *, state: Optional[Dict] = None,
                mesh_ctx=None):
    """The Griffin recurrent block: in-proj → causal conv → RG-LRU, gated.
    x: (B,S,d). ``state`` = {"conv": (B,K-1,W), "h": (B,W)} for decode.
    Returns (out (B,S,d), new_state)."""
    if mesh_ctx is not None:
        x = mesh_ctx.gather_seq(x)
    rec = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_y"]),
                       approximate=True)
    if mesh_ctx is not None:
        # TP: the recurrence is elementwise over the lru width — shard it
        dims = (mesh_ctx.data_axes, None, mesh_ctx.model_axis)
        rec = mesh_ctx.constrain_dims(rec, dims)
        gate = mesh_ctx.constrain_dims(gate, dims)
    conv_state = state["conv"] if state is not None else None
    rec, new_conv = _causal_conv(rec, params["conv_w"], params["conv_b"],
                                 conv_state)
    if state is None:
        h, h_last = rglru_scan(params, rec)
    else:
        h, h_last = rglru_step(params, rec, state["h"])
    out = jnp.einsum("bsw,wd->bsd", h * gate, params["w_out"])
    new_state = {"conv": new_conv.astype(x.dtype), "h": h_last}
    return out, new_state


def rglru_state_shape(cfg: ModelConfig, batch: int):
    W = cfg.lru_width
    return {"conv": (batch, cfg.conv_width - 1, W), "h": (batch, W)}


# ---------------------------------------------------------------------------
# RWKV6  (Finch, arXiv:2404.05892; structure-faithful, see DESIGN.md)
# ---------------------------------------------------------------------------

_RWKV_CHUNK = 16
_LOGW_MIN, _LOGW_MAX = -5.0, -1e-6
_LORA_DIM = 64


def rwkv_heads(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_heads, head_dim). We size heads to the TP degree (16); the
    assignment fixes only d_model/d_ff/vocab for rwkv6-3b."""
    H = 16 if cfg.d_model % 16 == 0 else 8
    return H, cfg.d_model // H


def rwkv_time_mix_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H, N = rwkv_heads(cfg)
    return {
        "mu_r": p((d,), ("embed",), init="zeros"),
        "mu_k": p((d,), ("embed",), init="zeros"),
        "mu_v": p((d,), ("embed",), init="zeros"),
        "mu_g": p((d,), ("embed",), init="zeros"),
        "mu_w": p((d,), ("embed",), init="zeros"),
        "wr": p((d, H, N), ("embed", "heads", "head_dim"), init="scaled"),
        "wk": p((d, H, N), ("embed", "heads", "head_dim"), init="scaled"),
        "wv": p((d, H, N), ("embed", "heads", "head_dim"), init="scaled"),
        "wg": p((d, H, N), ("embed", "heads", "head_dim"), init="scaled"),
        "w0": p((H, N), ("heads", "head_dim"), init="zeros"),
        "lora_wA": p((d, _LORA_DIM), ("embed", None), init="scaled"),
        "lora_wB": p((_LORA_DIM, H, N), (None, "heads", "head_dim"),
                     init="scaled"),
        "u": p((H, N), ("heads", "head_dim"), init="normal", scale=0.5),
        "ln_out": p((H, N), ("heads", "head_dim"), init="zeros"),
        "wo": p((H, N, d), ("heads", "head_dim", "embed"), init="scaled"),
    }


def _shift(x, state=None):
    """Token shift: x_{t-1}, with optional (B,d) carry-in for decode."""
    if state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([state[:, None, :].astype(x.dtype),
                                x[:, :-1]], axis=1)
    return prev


def _rwkv_proj(cfg, params, x, xprev):
    def mix(mu):
        return x + (xprev - x) * mu.astype(x.dtype)

    r = jnp.einsum("bsd,dhn->bshn", mix(params["mu_r"]), params["wr"])
    k = jnp.einsum("bsd,dhn->bshn", mix(params["mu_k"]), params["wk"])
    v = jnp.einsum("bsd,dhn->bshn", mix(params["mu_v"]), params["wv"])
    g = jnp.einsum("bsd,dhn->bshn", mix(params["mu_g"]), params["wg"])
    xw = mix(params["mu_w"]).astype(jnp.float32)
    lora = jnp.einsum("bsl,lhn->bshn",
                      jnp.tanh(xw @ params["lora_wA"].astype(jnp.float32)),
                      params["lora_wB"].astype(jnp.float32))
    logw = -jnp.exp(params["w0"].astype(jnp.float32) + lora)
    logw = jnp.clip(logw, _LOGW_MIN, _LOGW_MAX)
    return r, k, v, g, logw


def _rwkv_out(cfg, params, wkv, g, B, S):
    """Per-head RMS-norm, gate, out-projection. wkv: (B,S,H,N)."""
    xf = wkv.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + 1e-6)
    xf = xf * (1.0 + params["ln_out"].astype(jnp.float32))
    out = xf.astype(wkv.dtype) * jax.nn.silu(g)
    return jnp.einsum("bshn,hnd->bsd", out, params["wo"])


def rwkv_time_mix(cfg: ModelConfig, params, x, *, state: Optional[Dict] = None,
                  mesh_ctx=None):
    """x: (B,S,d). state = {"shift": (B,d), "S": (B,H,N,N) fp32} for decode.
    Returns (out, new_state)."""
    if mesh_ctx is not None:
        x = mesh_ctx.gather_seq(x)
    B, S, d = x.shape
    H, N = rwkv_heads(cfg)
    xprev = _shift(x, None if state is None else state["shift"])
    r, k, v, g, logw = _rwkv_proj(cfg, params, x, xprev)
    if mesh_ctx is not None:
        # TP over rwkv heads: wkv recurrence is independent per head
        dims = (mesh_ctx.data_axes, None, mesh_ctx.model_axis, None)
        r, k, v, g = (mesh_ctx.constrain_dims(t, dims) for t in (r, k, v, g))
    u = params["u"].astype(jnp.float32)

    if state is not None:                      # single-token decode
        rf, kf, vf = (t.astype(jnp.float32)[:, 0] for t in (r, k, v))
        S_prev = state["S"]                    # (B,H,N,N) fp32
        # out_t = r (S_prev + u ⊙ k v^T);  S = diag(w) S_prev + k v^T
        kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
        out = jnp.einsum("bhn,bhnm->bhm", rf, S_prev + u[None, :, :, None] * kv)
        S_new = jnp.exp(logw[:, 0])[..., None] * S_prev + kv
        wkv = out[:, None].astype(x.dtype).reshape(B, 1, H, N)
        y = _rwkv_out(cfg, params, wkv, g, B, S)
        return y, {"shift": x[:, -1, :], "S": S_new}

    # ---- chunked training/prefill path (fp32 core) -------------------------
    C = _RWKV_CHUNK
    S_p = -(-S // C) * C
    if S_p != S:
        # state-invariant padding: k=0 contributes nothing, logw=0 decays
        # nothing; padded outputs are sliced off below
        pad = ((0, 0), (0, S_p - S), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        logw = jnp.pad(logw, pad)
    nc = S_p // C

    def chunked(t):
        return t.astype(jnp.float32).reshape(B, nc, C, H, N)

    rc, kc, vc, lw = chunked(r), chunked(k), chunked(v), chunked(logw)
    lc = jnp.cumsum(lw, axis=2)                         # inclusive log-decay
    lce = lc - lw                                       # exclusive
    a0 = lc[:, :, :1]                                   # per-chunk shift
    q_in = rc * jnp.exp(lce - a0)                       # bounded exponents
    k_in = kc * jnp.exp(a0 - lc)
    scores = jnp.einsum("bcthn,bcjhn->bchtj", q_in, k_in)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)       # strict lower
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    out_intra = jnp.einsum("bchtj,bcjhn->bcthn", scores, vc)
    # current-token bonus
    bonus = jnp.einsum("bcthn,bcthn->bcth", rc, u[None, None, None] * kc)
    out_intra = out_intra + bonus[..., None] * vc
    # chunk summaries: D = decay over the chunk; M = sum decayed k v^T
    last = lc[:, :, -1:]                                # (B,nc,1,H,N)
    Dc = jnp.exp(last[:, :, 0])                         # (B,nc,H,N)
    k_out = kc * jnp.exp(last - lc)
    Mc = jnp.einsum("bcthn,bcthm->bchnm", k_out, vc)    # (B,nc,H,N,N)

    def combine(x1, x2):
        d1, m1 = x1
        d2, m2 = x2
        return d1 * d2, d2[..., None] * m1 + m2

    Dcum, Mcum = jax.lax.associative_scan(combine, (Dc, Mc), axis=1)
    # state entering chunk c = cumulative through c-1 (exclusive shift)
    S_prev = jnp.pad(Mcum, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    out_inter = jnp.einsum("bcthn,bchnm->bcthm", q_in * jnp.exp(a0), S_prev)
    wkv = (out_intra + out_inter).reshape(B, S_p, H, N)[:, :S].astype(x.dtype)
    y = _rwkv_out(cfg, params, wkv, g, B, S)
    new_state = {"shift": x[:, -1, :], "S": Mcum[:, -1]}
    return y, new_state


def rwkv_channel_mix_spec(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": p((d,), ("embed",), init="zeros"),
        "mu_r": p((d,), ("embed",), init="zeros"),
        "wk": p((d, f), ("embed", "ff"), init="scaled"),
        "wv": p((f, d), ("ff", "embed"), init="scaled"),
        "wr": p((d, d), ("embed", None), init="scaled"),
    }


def rwkv_channel_mix(cfg: ModelConfig, params, x, *,
                     state: Optional[jax.Array] = None, mesh_ctx=None):
    """RWKV6 FFN with token shift. state: (B,d) last token (decode)."""
    if mesh_ctx is not None:
        x = mesh_ctx.gather_seq(x)
    xprev = _shift(x, state)

    def mix(mu):
        return x + (xprev - x) * mu.astype(x.dtype)

    kx = jnp.einsum("bsd,df->bsf", mix(params["mu_k"]), params["wk"])
    if mesh_ctx is not None:
        kx = mesh_ctx.constrain_dims(
            kx, (mesh_ctx.data_axes, None, mesh_ctx.model_axis))
    kx = jnp.square(jax.nn.relu(kx))
    vx = jnp.einsum("bsf,fd->bsd", kx, params["wv"])
    rx = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", mix(params["mu_r"]),
                                   params["wr"]))
    return rx * vx, x[:, -1, :]


def rwkv_state_shape(cfg: ModelConfig, batch: int):
    H, N = rwkv_heads(cfg)
    return {
        "tm_shift": (batch, cfg.d_model),
        "S": (batch, H, N, N),
        "cm_shift": (batch, cfg.d_model),
    }

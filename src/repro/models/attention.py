"""Memory-efficient attention (flash-style online softmax) in pure JAX.

XLA materializes the full (Sq, Skv) logit matrix of a plain softmax
attention — at 32k x 32k that is petabytes; chunked attention is mandatory
for the prefill/train cells. This module is the *compiled* (XLA) twin of
``repro.kernels.flash_attention`` (the Pallas TPU kernel): same algorithm,
same chunking, so the dry-run roofline reflects what the kernel does on
real hardware. ``kernels/ref.py`` cross-checks both against the naive
oracle.

Two causal schedules:

* ``exact_causal=True`` (default): a static python loop over query chunks;
  query chunk ``i`` scans only the ``i+1`` KV chunks of its prefix — the
  compiled FLOPs match the causal-optimal count (no upper-triangle waste).
  This is the grid-pruning that the Pallas kernel does on TPU.
* ``exact_causal=False``: one uniform ``lax.scan`` over all KV chunks with
  masking — simpler HLO, ~2x attention-score FLOPs on causal inputs. Kept
  as the §Perf baseline knob.

Sliding-window (local) layers take a banded schedule: query chunk ``i``
attends KV chunks ``[i-w/qc, i]`` only.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_NEG_INF = -1e30

# Cost-extraction switch (benchmarks/roofline.py): XLA counts a while-loop
# body once regardless of trip count, so the roofline pass unrolls the
# inner KV-chunk scans to make cost_analysis see every chunk.
UNROLL_INNER = False


def _chunk_logits(q, k, softcap):
    """q: (B,qc,H,D); k: (B,kc,H,D) -> fp32 (B,H,qc,kc)."""
    D = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(D)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def _mask(q0, k0, qc, kc, *, causal, window, prefix_len, kv_len=None):
    qpos = q0 + jnp.arange(qc)[:, None]
    kpos = k0 + jnp.arange(kc)[None, :]
    m = jnp.ones((qc, kc), bool)
    if causal:
        m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    if prefix_len:
        m |= kpos < prefix_len
    if kv_len is not None:
        m &= kpos < kv_len          # mask padded KV positions
    return m


def _expand_kv(k, n_rep: int):
    """GQA: (B,S,KV,D) -> (B,S,H,D) by repeating each KV head. Chunk-local,
    so the expansion never materializes beyond one KV chunk."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def _attend_chunk(state, q, k_chunk, v_chunk, mask, softcap):
    """Online-softmax accumulation of one KV chunk.
    state: (m (B,H,qc), l (B,H,qc), acc (B,H,qc,D))."""
    m_prev, l_prev, acc = state
    logits = _chunk_logits(q, k_chunk, softcap)               # (B,H,qc,kc)
    logits = jnp.where(mask[None, None], logits, _NEG_INF)
    m_cur = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == -inf)
    safe_m = jnp.where(m_new <= _NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(logits - safe_m[..., None])
    p = jnp.where(mask[None, None], p, 0.0)
    alpha = jnp.where(m_prev <= _NEG_INF / 2, 0.0,
                      jnp.exp(m_prev - safe_m))
    l_new = alpha * l_prev + p.sum(-1)
    acc = alpha[..., None] * acc + jnp.einsum(
        "bhqk,bkhd->bhqd", p, v_chunk.astype(jnp.float32))
    return m_new, l_new, acc


def _finalize(state, dtype):
    _, l, acc = state
    out = acc / jnp.maximum(l, 1e-30)[..., None]              # (B,H,qc,D)
    return out.transpose(0, 2, 1, 3).astype(dtype)            # (B,qc,H,D)


def _init_state(B, H, qc, D):
    return (jnp.full((B, H, qc), _NEG_INF, jnp.float32),
            jnp.zeros((B, H, qc), jnp.float32),
            jnp.zeros((B, H, qc, D), jnp.float32))


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      prefix_len: int = 0,
                      q_chunk: int = 2048, kv_chunk: int = 2048,
                      exact_causal: bool = True) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D) with H % KV == 0. Self-attention
    layout (Sq == Skv, same positions). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    n_rep = H // KV
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    # pad to chunk multiples; padded KV columns are masked, padded query
    # rows are sliced off the output
    Sq_p = -(-Sq // qc) * qc
    Skv_p = -(-Skv // kc) * kc
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        pad = ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nq, nk = Sq_p // qc, Skv_p // kc
    kv_len = Skv if Skv_p != Skv else None

    def kv_slice(j0, n):
        ks = jax.lax.dynamic_slice_in_dim(k, j0 * kc, n * kc, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, j0 * kc, n * kc, axis=1)
        return ks, vs

    outs = []
    for i in range(nq):
        qi = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
        q0 = i * qc
        prefix_hi = -(-prefix_len // kc) if prefix_len else 0
        if causal and window is not None:
            # banded: only chunks intersecting [q0 - window + 1, q0 + qc)
            j_lo = max(0, (q0 - window + 1) // kc)
            j_hi = min(nk, max((q0 + qc + kc - 1) // kc, prefix_hi))
            if prefix_len:
                j_lo = 0                      # prefix chunks always visible
        elif causal and exact_causal:
            j_lo = 0
            j_hi = min(nk, max((q0 + qc + kc - 1) // kc, prefix_hi))
        else:
            j_lo, j_hi = 0, nk

        span = j_hi - j_lo
        ks, vs = kv_slice(j_lo, span)
        # keep KV heads compact here; the GQA expansion happens per chunk
        # inside the scan body (expanding the whole span materializes a
        # full-sequence H-headed copy — observed ~1 GiB/device at 32k)
        kcs = ks.reshape(B, span, kc, KV, D).transpose(1, 0, 2, 3, 4)
        vcs = vs.reshape(B, span, kc, KV, D).transpose(1, 0, 2, 3, 4)
        k0s = (j_lo + jnp.arange(span)) * kc

        def body(state, xs):
            k_chunk, v_chunk, k0 = xs
            k_chunk = _expand_kv(k_chunk, n_rep)
            v_chunk = _expand_kv(v_chunk, n_rep)
            mask = _mask(q0, k0, qc, kc, causal=causal, window=window,
                         prefix_len=prefix_len, kv_len=kv_len)
            return _attend_chunk(state, qi, k_chunk, v_chunk, mask,
                                 softcap), None

        state, _ = jax.lax.scan(body, _init_state(B, H, qc, D),
                                (kcs, vcs, k0s),
                                unroll=span if UNROLL_INNER else 1)
        outs.append(_finalize(state, q.dtype))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out[:, :Sq] if Sq_p != Sq else out


def reference_attention(q, k, v, *, causal=True, window=None, softcap=None,
                        prefix_len: int = 0) -> jax.Array:
    """Naive full-matrix oracle (fp32) — small shapes only."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    ke = _expand_kv(k, H // KV)
    ve = _expand_kv(v, H // KV)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        ke.astype(jnp.float32)) / np.sqrt(D)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = _mask(0, 0, Sq, k.shape[1], causal=causal, window=window,
                 prefix_len=prefix_len)
    logits = jnp.where(mask[None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, ve.astype(jnp.float32))
    return out.astype(q.dtype)

"""Shared layers: norms, RoPE, embeddings, attention (GQA, sliding-window,
softcap, bias), MLPs. Pure functions over param dicts; fp32 where numerics
demand it (norms, softmax, rope), bf16 elsewhere.

Sequence-dim sharding constraints (SP) are applied by the caller via
``repro.sharding.constrain`` so the layer code stays mesh-agnostic.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, p

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int):
    return {"scale": p((dim,), ("embed",), init="zeros")}  # (1+scale) param.


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_spec(dim: int):
    return {"scale": p((dim,), ("embed",), init="ones"),
            "bias": p((dim,), ("embed",), init="zeros")}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def norm_spec(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    return layernorm_spec(dim) if cfg.norm == "layernorm" else rmsnorm_spec(dim)


def norm(cfg: ModelConfig, params, x):
    return layernorm(params, x) if cfg.norm == "layernorm" else rmsnorm(params, x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_spec(cfg: ModelConfig, cross: bool = False) -> Dict:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.d_head
    spec = {
        "wq": p((d, H, Dh), ("embed", "heads", "head_dim"), init="scaled"),
        "wk": p((d, KV, Dh), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": p((d, KV, Dh), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wo": p((H, Dh, d), ("heads", "head_dim", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        spec["bq"] = p((H, Dh), ("heads", "head_dim"), init="zeros")
        spec["bk"] = p((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = p((KV, Dh), ("kv_heads", "head_dim"), init="zeros")
    return spec


def _qkv(cfg: ModelConfig, params, xq, xkv):
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D); mask: (B|1, 1, Sq, Skv) bool."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    groups = H // KV
    qg = q.reshape(B, Sq, KV, groups, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(D)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    logits = jnp.where(mask[:, :, None, :, :] if mask.ndim == 4 else mask,
                       logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def causal_mask(Sq: int, Skv: int, q_offset=0, window: Optional[int] = None):
    """(1,1,Sq,Skv) bool. ``q_offset``: absolute position of query 0 (may be
    a traced scalar). ``window``: sliding window (local attention)."""
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None]


def _use_chunked(cfg: ModelConfig, Sq: int) -> bool:
    if cfg.attn_impl == "chunked":
        return True
    if cfg.attn_impl == "xla":
        return False
    return Sq > 2048  # auto: full logits past 2k are prohibitive


def _use_flash_decode(cfg: ModelConfig) -> bool:
    """Route decode attention through the Pallas flash-decoding kernels
    (plain or paged). "auto" compiles the real Mosaic kernels on TPU and
    keeps the dense-mask XLA path elsewhere — off-TPU the kernels only
    run in interpret mode, which validates tiling but wins nothing."""
    if cfg.decode_kernel == "flash":
        return True
    if cfg.decode_kernel == "xla":
        return False
    return jax.default_backend() == "tpu"


def _paged_attention(cfg: ModelConfig, q, k_pages, v_pages, tables, qpos):
    """Attention for a (B,Sq,H,D) query chunk straight out of KV pool
    pages: ``k_pages``/``v_pages`` are (num_blocks, bt, KV, D), block
    ``i`` of ``tables[b]`` backs logical positions [i*bt, (i+1)*bt) and
    query token (b, j) attends positions <= qpos[b, j]. The kernel path
    streams K/V tiles from pool rows named by the (scalar-prefetched)
    table; the XLA path gathers the pages and reuses ``_sdpa`` so the
    numerics match the gather engine's dense decode exactly."""
    if _use_flash_decode(cfg):
        from ..kernels import paged_decode_attention
        return paged_decode_attention(q, k_pages, v_pages, tables, qpos,
                                      softcap=cfg.attn_logit_softcap)
    B, Sq = q.shape[:2]
    NW, bt = tables.shape[1], k_pages.shape[-3]
    kc = k_pages[tables].reshape((B, NW * bt) + k_pages.shape[-2:])
    vc = v_pages[tables].reshape((B, NW * bt) + v_pages.shape[-2:])
    m = jnp.arange(NW * bt)[None, None, :] <= qpos[:, :, None]
    return _sdpa(cfg, q, kc, vc, m[:, None])


def _paged_write_attend(cfg: ModelConfig, q, k, v, kp, vp, tables, lens,
                        cache_pos):
    """Zero-copy paged data plane: write the chunk's k/v into the pool
    rows the block table names, attend straight out of the pool. Returns
    (out, new_k_pages, new_v_pages). Also the per-device body of the TP
    shard_map — q/k/v/pages arrive head-sliced there, everything else
    replicated, and the ops below never mix KV heads."""
    B, Sq = q.shape[:2]
    bt = kp.shape[-3]
    tpos = cache_pos[:, None] + jnp.arange(Sq)[None, :]          # (B,Sq)
    blk = jnp.minimum(tpos // bt, tables.shape[1] - 1)
    rows = jnp.take_along_axis(tables, blk, axis=1)
    # right-padded (and inactive-slot) tokens land in pool row 0, the
    # engine's reserved junk row — real rows only ever see writes of real
    # tokens
    rows = jnp.where(jnp.arange(Sq)[None, :] < lens[:, None], rows, 0)
    widx = (rows.reshape(-1), (tpos % bt).reshape(-1))
    ck = kp.at[widx].set(k.reshape((B * Sq,) + k.shape[2:]).astype(kp.dtype))
    cv = vp.at[widx].set(v.reshape((B * Sq,) + v.shape[2:]).astype(vp.dtype))
    out = _paged_attention(cfg, q, ck, cv, tables, tpos)
    return out, ck, cv


def _paged_write_attend_tp(cfg: ModelConfig, kv_shard, q, k, v, kp, vp,
                           tables, lens, cache_pos):
    """Tensor-parallel paged write+attend: the per-device body above under
    ``shard_map``, q/k/v and the pool pages sliced on their head dims, the
    block table (and every other host-derived operand) replicated. GQA
    packing groups queries by KV head, so a contiguous H/tp query slice
    owns exactly its KV slice's whole head groups — no cross-device
    attention math. The attention outputs are all-gathered over heads
    inside the map so the (replicated) ``wo`` projection runs on the full
    head set on every device: at tp=1 the gather is the identity, and at
    any tp the summation ORDER of the output projection is the single-
    device order — generations stay token-identical (the psum formulation
    would instead reduce partial wo products in mesh order, perturbing
    bf16 rounding). The pages come back head-sharded, matching the pool's
    committed layout, so the engine's donated step keeps them in place."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    ax = kv_shard.axis
    heads = PartitionSpec(None, None, ax, None)   # q/k/v (B,S,h,D) and
    repl = PartitionSpec()                        # pages (nb,bt,kv,D)

    def body(q, k, v, kp, vp, tables, lens, cache_pos):
        out, ck, cv = _paged_write_attend(cfg, q, k, v, kp, vp, tables,
                                          lens, cache_pos)
        out = jax.lax.all_gather(out, ax, axis=2, tiled=True)
        return out, ck, cv

    return shard_map(
        body, mesh=kv_shard.mesh,
        in_specs=(heads, heads, heads, heads, heads, repl, repl, repl),
        out_specs=(PartitionSpec(), heads, heads),
        check_rep=False,
    )(q, k, v, kp, vp, tables, lens, cache_pos)


def _tp_qkv_constraints(mesh_ctx, q, k, v):
    """Inside the TP region: heads over model, batch over data. When the
    head count does not divide the model axis (qwen2: 28H, whisper: 8H on
    TP=16), fall back to CONTEXT parallelism for long inputs: queries
    sharded over model along the sequence (each rank attends its query
    slice against replicated KV) — otherwise a 32k prefill keeps full
    (B, S, H, D) projections replicated on every chip."""
    dp, mdl = mesh_ctx.data_axes, mesh_ctx.model_axis
    tp = mesh_ctx.tp_size
    H = q.shape[2]
    if H % max(tp, 1) == 0 or tp <= 1:
        q = mesh_ctx.constrain_dims(q, (dp, None, mdl, None))
        k = mesh_ctx.constrain_dims(k, (dp, None, mdl, None))
        v = mesh_ctx.constrain_dims(v, (dp, None, mdl, None))
    elif q.shape[1] > 1 and q.shape[1] % tp == 0:
        q = mesh_ctx.constrain_dims(q, (dp, mdl, None, None))
        k = mesh_ctx.constrain_dims(k, (dp, None, None, None))
        v = mesh_ctx.constrain_dims(v, (dp, None, None, None))
    return q, k, v


def attention(cfg: ModelConfig, params, x, *, positions, window=None,
              cache: Optional[Dict] = None, cache_pos=None,
              cache_valid_len=None, paged: Optional[Dict] = None,
              cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              bidirectional: bool = False, prefix_len: int = 0,
              mesh_ctx=None, kv_shard=None):
    """Full attention layer (proj → rope → sdpa → proj).

    Modes:
      * training/prefill: cache=None, causal (or bidirectional for encoders)
      * decode: ``cache`` = {"k","v"} (B, S_cache, KV, D); the new token is
        written at slot ``cache_pos`` (callers pass ``pos % window`` for
        rolling local-attention caches) and attends to the first
        ``cache_valid_len`` slots. Keys keep the RoPE phase of the absolute
        position they were written with, so slot order is irrelevant.
      * paged decode: ``cache`` = {"k","v"} per-layer KV *pool* views
        (num_blocks, bt, KV, D) and ``paged`` = {"tables": (B, NW) pool
        rows in chain order, "seq_lens": (B,) real tokens per row}. Each
        slot's chunk is written into the tail pool rows its block table
        names (right-padded and inactive-slot tokens land in reserved junk
        row 0) and attention streams from the table's rows — no per-slot
        contiguous cache exists. Absolute positions only (G/M layers).
      * cross: ``cross_kv`` provides precomputed (k, v) from the encoder.
    Returns (out, new_cache).
    """
    B, Sq, d = x.shape
    if mesh_ctx is not None:
        x = mesh_ctx.gather_seq(x)     # SP all-gather on TP-region entry
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        if cfg.qkv_bias:
            q = q + params["bq"]
        k, v = cross_kv
        if mesh_ctx is not None:
            q, k, v = _tp_qkv_constraints(mesh_ctx, q, k, v)
        mask = jnp.ones((1, 1, Sq, k.shape[1]), bool)
        out = _sdpa(cfg, q, k, v, mask)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache

    q, k, v = _qkv(cfg, params, x, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if mesh_ctx is not None:
        q, k, v = _tp_qkv_constraints(mesh_ctx, q, k, v)

    if cache is not None:
        # ``cache_valid_len`` is the valid cache length as seen by the
        # FIRST query token; query token j of a chunk sees j more (its own
        # write and its intra-chunk predecessors) — per-token causality for
        # Sq > 1 (chunked prefill), and exactly the old semantics at Sq=1.
        if paged is not None:
            # zero-copy paged data plane: write the chunk into the pool
            # rows the block table names, attend straight out of the pool
            # (one shard_map over the head-sharded pool under serve TP)
            tables, lens = paged["tables"], paged["seq_lens"]
            fn = (partial(_paged_write_attend_tp, cfg, kv_shard)
                  if kv_shard is not None
                  else partial(_paged_write_attend, cfg))
            out, ck, cv = fn(q, k, v, cache["k"], cache["v"], tables,
                             lens, cache_pos)
        elif getattr(cache_pos, "ndim", 0) == 1:
            # per-slot positions (continuous batching): each slot scatters
            # its Sq-token chunk at its own offset. Positions are absolute
            # (slot order == position) — rolling-window caches take the
            # bulk path.
            bidx = jnp.arange(B)[:, None]                        # (B,1)
            tpos = cache_pos[:, None] + jnp.arange(Sq)[None, :]  # (B,Sq)
            ck = cache["k"].at[bidx, tpos].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, tpos].set(v.astype(cache["v"].dtype))
            Skv = ck.shape[1]
            base = (cache_pos + 1 if cache_valid_len is None
                    else cache_valid_len)
            if Sq == 1 and _use_flash_decode(cfg):
                # flash-decoding: split-K streaming over the valid cache,
                # no dense (Sq, Skv) mask materialized
                from ..kernels import decode_attention as _flash_dec
                out = _flash_dec(q[:, 0], ck, cv, base,
                                 softcap=cfg.attn_logit_softcap)[:, None]
            else:
                valid = base[:, None] + jnp.arange(Sq)[None, :]  # (B,Sq)
                m = jnp.arange(Skv)[None, None, :] < valid[:, :, None]
                out = _sdpa(cfg, q, ck, cv, m[:, None])          # (B,1,Sq,Skv)
        else:
            # bulk decode: one shared position, dynamic-update-slice
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
            Skv = ck.shape[1]
            base = (cache_pos + 1 if cache_valid_len is None
                    else cache_valid_len)
            if Sq == 1 and _use_flash_decode(cfg):
                # rolling (L) caches pass valid = min(pos+1, window): the
                # whole wrapped buffer is live, so no window mask applies
                # to cache slots and slot order stays irrelevant
                from ..kernels import decode_attention as _flash_dec
                out = _flash_dec(q[:, 0], ck, cv,
                                 jnp.broadcast_to(base, (B,)),
                                 softcap=cfg.attn_logit_softcap)[:, None]
            else:
                valid = base + jnp.arange(Sq)                    # (Sq,)
                m = jnp.arange(Skv)[None, :] < valid[:, None]    # (Sq,Skv)
                out = _sdpa(cfg, q, ck, cv, m[None, None, :, :])
        new_cache = {"k": ck, "v": cv}
    else:
        if _use_chunked(cfg, Sq):
            from .attention import chunked_attention
            out = chunked_attention(
                q, k, v, causal=not bidirectional, window=window,
                softcap=cfg.attn_logit_softcap, prefix_len=prefix_len,
                q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                exact_causal=cfg.exact_causal)
        else:
            if bidirectional:
                mask = jnp.ones((1, 1, Sq, Sq), bool)
            else:
                qpos = jnp.arange(Sq)[:, None]
                kpos = jnp.arange(Sq)[None, :]
                m = kpos <= qpos
                if window is not None:
                    m &= kpos > qpos - window
                if prefix_len:
                    m |= kpos < prefix_len
                mask = m[None, None]
            out = _sdpa(cfg, q, k, v, mask)
        new_cache = None
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache


def cross_kv_spec(cfg: ModelConfig):
    """Encoder-side projections for cross attention (computed once)."""
    return {
        "wk": p((cfg.d_model, cfg.kv_heads, cfg.d_head),
                ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": p((cfg.d_model, cfg.kv_heads, cfg.d_head),
                ("embed", "kv_heads", "head_dim"), init="scaled"),
    }


def make_cross_kv(params, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {"wi": p((d, 2, f), ("embed", None, "ff"), init="scaled"),
                "wo": p((f, d), ("ff", "embed"), init="scaled")}
    return {"wi": p((d, 1, f), ("embed", None, "ff"), init="scaled"),
            "wo": p((f, d), ("ff", "embed"), init="scaled")}


def mlp(cfg: ModelConfig, params, x, mesh_ctx=None):
    if mesh_ctx is not None:
        x = mesh_ctx.gather_seq(x)     # SP all-gather on TP-region entry
    h = jnp.einsum("bsd,dcf->bscf", x, params["wi"])
    if mesh_ctx is not None:
        # Megatron TP: intermediate sharded over model along d_ff — the
        # second matmul then emits partial sums that reduce-scatter back
        # into the SP layout at the residual add.
        h = mesh_ctx.constrain_dims(
            h, (mesh_ctx.data_axes, None, None, mesh_ctx.model_axis))
    if cfg.act == "swiglu":
        h = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    elif cfg.act == "geglu":
        h = jax.nn.gelu(h[..., 0, :], approximate=True) * h[..., 1, :]
    else:
        h = jax.nn.gelu(h[..., 0, :], approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(cfg: ModelConfig) -> Dict:
    spec = {"tok": p((cfg.vocab, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        spec["unembed"] = p((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return spec


def embed(cfg: ModelConfig, params, tokens):
    h = params["tok"].astype(cfg.dtype)[tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    return h


def unembed(cfg: ModelConfig, params, h, mesh_ctx=None):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"])
    if mesh_ctx is not None:
        # vocab-parallel logits: the unembedding stays sharded over model;
        # the CE loss's logsumexp/gather psum over the vocab shards.
        logits = mesh_ctx.constrain_dims(
            logits, (mesh_ctx.data_axes, None, mesh_ctx.model_axis))
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = (c * jnp.tanh(logits.astype(jnp.float32) / c)).astype(logits.dtype)
    return logits

"""Mixture-of-Experts layer.

Two execution paths with identical math:

* ``_moe_local``  — reference: dense compute of all experts, exact top-k
  combine (no capacity drops). Used for single-device smoke tests and as
  the oracle for the distributed path.
* ``_moe_ep``     — production: experts sharded over the ``model`` mesh
  axis (EP) inside ``shard_map``. Tokens are replicated across the model
  axis (they already are, in TP attention blocks); each model rank gathers
  the tokens routed to *its* experts into fixed-capacity buffers
  (capacity-factor dropping, Switch-style), runs grouped GEMMs, scatters
  back, and one ``psum`` over the model axis combines partial outputs —
  the same collective pattern as a TP MLP, so no extra all-to-alls.
  Compiled FLOPs are *active-expert* FLOPs (roofline honesty), not dense.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ModelConfig, p


def moe_spec(cfg: ModelConfig) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    nc = 2 if cfg.act in ("swiglu", "geglu") else 1
    spec = {
        "router": p((d, E), ("embed", "experts"), init="scaled"),
        "wi": p((E, d, nc, f), ("experts", "embed", None, "ff"), init="scaled"),
        "wo": p((E, f, d), ("experts", "ff", "embed"), init="scaled"),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        spec["shared_wi"] = p((d, nc, fs), ("embed", None, "ff"), init="scaled")
        spec["shared_wo"] = p((fs, d), ("ff", "embed"), init="scaled")
    return spec


def _act(cfg: ModelConfig, h):
    # h: (..., nc, f)
    if cfg.act == "swiglu":
        return jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    if cfg.act == "geglu":
        return jax.nn.gelu(h[..., 0, :], approximate=True) * h[..., 1, :]
    return jax.nn.gelu(h[..., 0, :], approximate=True)


def _route(cfg: ModelConfig, router_w, x_flat):
    """(T,d) -> (T,k) weights and (T,k) expert ids; softmax→top-k→renorm."""
    logits = (x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi


def _shared(cfg: ModelConfig, params, x_flat):
    h = jnp.einsum("td,dcf->tcf", x_flat, params["shared_wi"])
    return jnp.einsum("tf,fd->td", _act(cfg, h), params["shared_wo"])


def _moe_local(cfg: ModelConfig, params, x_flat):
    """Exact dense reference: every expert on every token, masked combine."""
    topw, topi = _route(cfg, params["router"], x_flat)
    h = jnp.einsum("td,edcf->tecf", x_flat, params["wi"])    # all experts
    y = jnp.einsum("tef,efd->ted", _act(cfg, h), params["wo"])
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=x_flat.dtype)  # (T,k,E)
    w = jnp.einsum("tk,tke->te", topw.astype(x_flat.dtype), onehot)
    out = jnp.einsum("ted,te->td", y, w)
    if cfg.n_shared_experts:
        out = out + _shared(cfg, params, x_flat)
    return out


def _expert_compute(cfg, wi, wo, gathered):
    """gathered: (E_loc, C, d) -> (E_loc, C, d)."""
    h = jnp.einsum("ecd,ednf->ecnf", gathered, wi)
    return jnp.einsum("ecf,efd->ecd", _act(cfg, h), wo)


def _moe_ep_device(cfg: ModelConfig, model_axis: str, params, x_flat):
    """Per-device body under shard_map. x_flat: (T_loc, d) — replicated
    across the model axis; experts: local slice (E_loc, ...)."""
    E = cfg.n_experts
    E_loc = params["wi"].shape[0]
    n_shards = E // E_loc
    rank = jax.lax.axis_index(model_axis)
    T, d = x_flat.shape
    k = cfg.top_k
    C = max(1, math.ceil(T * k * cfg.capacity_factor / E))

    topw, topi = _route(cfg, params["router"], x_flat)      # (T,k)
    flat_e = topi.reshape(-1)                               # (T*k,)
    flat_w = topw.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T), k)

    my_first = rank * E_loc
    local = (flat_e >= my_first) & (flat_e < my_first + E_loc)
    eid = jnp.where(local, flat_e - my_first, E_loc)        # E_loc = trash bin
    onehot = jax.nn.one_hot(eid, E_loc + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1           # (T*k, E_loc+1)
    pos = pos.max(axis=1)                                   # slot within expert
    keep = local & (pos < C) & (pos >= 0)
    slot = jnp.where(keep, eid * C + pos, E_loc * C)        # overflow slot

    # scatter token indices / gates into capacity buffers (+1 overflow row)
    buf_tok = jnp.zeros((E_loc * C + 1,), jnp.int32).at[slot].set(tok_of)
    buf_gate = jnp.zeros((E_loc * C + 1,), flat_w.dtype).at[slot].set(
        jnp.where(keep, flat_w, 0.0))
    buf_tok, buf_gate = buf_tok[:-1], buf_gate[:-1]

    gathered = x_flat[buf_tok].reshape(E_loc, C, d)
    y = _expert_compute(cfg, params["wi"], params["wo"], gathered)
    y = y.reshape(E_loc * C, d) * buf_gate[:, None].astype(y.dtype)

    out = jnp.zeros((T, d), y.dtype).at[buf_tok].add(y)
    if cfg.n_shared_experts:
        # shared expert ff is sharded over the model axis (TP): partial sums
        out = out + _shared(cfg, params, x_flat)
    return jax.lax.psum(out, model_axis)


def moe(cfg: ModelConfig, params, x, mesh_ctx=None):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    if mesh_ctx is None or mesh_ctx.mesh is None:
        out = _moe_local(cfg, params, x.reshape(-1, d))
        return out.reshape(B, S, d)

    mc = mesh_ctx
    dp = mc.data_axes              # e.g. ("pod", "data")
    mdl = mc.model_axis            # "model"
    nc = 2 if cfg.act in ("swiglu", "geglu") else 1

    in_specs = (
        P(dp, None, None),                                  # x: batch-sharded
        {
            "router": P(None, None),
            "wi": P(mdl, None, None, None),
            "wo": P(mdl, None, None),
            **({"shared_wi": P(None, None, mdl),
                "shared_wo": P(mdl, None)} if cfg.n_shared_experts else {}),
        },
    )
    out_spec = P(dp, None, None)

    def body(xb, prm):
        Bl, Sl, _ = xb.shape
        out = _moe_ep_device(cfg, mdl, prm, xb.reshape(Bl * Sl, d))
        return out.reshape(Bl, Sl, d)

    pspec = {k: v for k, v in params.items()}
    return jax.shard_map(body, mesh=mc.mesh, in_specs=in_specs,
                         out_specs=out_spec, check_vma=False)(x, pspec)

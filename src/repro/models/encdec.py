"""Encoder-decoder transformer (whisper-base backbone).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings ``(B, T_frames, d_model)`` (what whisper's two
stride-2 convs would emit), so the encoder here is the transformer backbone
only. Whisper uses pre-LN LayerNorm blocks, GELU MLPs, learned positions on
the decoder, sinusoidal on the encoder, and MHA (kv == heads).

The decoder caches both its self-attention KV (grows with decoding) and the
cross-attention KV (computed once from the encoder output at prefill).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .common import ModelConfig, ParamSpec, p

# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------


def _enc_layer_spec(cfg: ModelConfig) -> Dict:
    return {
        "ln1": L.norm_spec(cfg),
        "attn": L.attention_spec(cfg),
        "ln2": L.norm_spec(cfg),
        "mlp": L.mlp_spec(cfg),
    }


def _dec_layer_spec(cfg: ModelConfig) -> Dict:
    return {
        "ln1": L.norm_spec(cfg),
        "self_attn": L.attention_spec(cfg),
        "ln_x": L.norm_spec(cfg),
        "cross_q": L.attention_spec(cfg),       # wq/wo used; wk/wv unused
        "cross_kv": L.cross_kv_spec(cfg),
        "ln2": L.norm_spec(cfg),
        "mlp": L.mlp_spec(cfg),
    }


def _stack(tree, n: int):
    def walk(t):
        if isinstance(t, dict):
            return {k: walk(v) for k, v in t.items()}
        s: ParamSpec = t
        return ParamSpec((n,) + s.shape, ("layer",) + s.axes, s.init,
                         s.scale, s.dtype)
    return walk(tree)


def encdec_spec(cfg: ModelConfig) -> Dict:
    assert cfg.n_encoder_layers > 0
    return {
        "embed": L.embed_spec(cfg),
        # decoder learned positions (whisper)
        "pos_dec": p((cfg.max_seq_len, cfg.d_model), (None, "embed"),
                     init="normal", scale=0.01),
        "enc_stack": _stack(_enc_layer_spec(cfg), cfg.n_encoder_layers),
        "ln_enc": L.norm_spec(cfg),
        "dec_stack": _stack(_dec_layer_spec(cfg), cfg.n_layers),
        "ln_f": L.norm_spec(cfg),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def _sinusoid(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d)
    return np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)


def encode(cfg: ModelConfig, params, frames, *, mesh_ctx=None,
           unroll: int = 1):
    """frames: (B, T, d_model) stub frame embeddings -> (B, T, d_model)."""
    B, T, d = frames.shape
    h = frames.astype(cfg.dtype)
    h = h + jnp.asarray(_sinusoid(T, d), cfg.dtype)[None]
    positions = jnp.arange(T)[None, :]
    if mesh_ctx is not None:
        h = mesh_ctx.shard_activations(h)

    def layer(h, prm):
        x = L.norm(cfg, prm["ln1"], h)
        a, _ = L.attention(cfg, prm["attn"], x, positions=positions,
                           bidirectional=True, mesh_ctx=mesh_ctx)
        h = h + a
        h = h + L.mlp(cfg, prm["mlp"], L.norm(cfg, prm["ln2"], h), mesh_ctx)
        if mesh_ctx is not None:
            h = mesh_ctx.shard_activations(h)
        return h

    body = jax.checkpoint(lambda c, prm: (layer(c, prm), None))
    h, _ = jax.lax.scan(body, h, params["enc_stack"], unroll=unroll)
    return L.norm(cfg, params["ln_enc"], h)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _dec_layer(cfg, prm, h, positions, cross_kv, *, cache=None,
               cache_pos=None, mesh_ctx=None):
    x = L.norm(cfg, prm["ln1"], h)
    a, new_cache = L.attention(cfg, prm["self_attn"], x, positions=positions,
                               cache=cache, cache_pos=cache_pos,
                               mesh_ctx=mesh_ctx)
    h = h + a
    x = L.norm(cfg, prm["ln_x"], h)
    c, _ = L.attention(cfg, prm["cross_q"], x, positions=positions,
                       cross_kv=cross_kv, mesh_ctx=mesh_ctx)
    h = h + c
    h = h + L.mlp(cfg, prm["mlp"], L.norm(cfg, prm["ln2"], h), mesh_ctx)
    return h, new_cache


def decode_train(cfg: ModelConfig, params, tokens, enc_out, *, mesh_ctx=None,
                 unroll: int = 1, last_logit_only: bool = False):
    """Teacher-forced decoder pass. tokens: (B, S) -> logits (B, S, vocab)."""
    B, S = tokens.shape
    h = L.embed(cfg, params["embed"], tokens)
    h = h + params["pos_dec"].astype(h.dtype)[:S][None]
    positions = jnp.arange(S)[None, :]
    if mesh_ctx is not None:
        h = mesh_ctx.shard_activations(h)

    def layer(h, prm):
        ckv = L.make_cross_kv(prm["cross_kv"], enc_out)
        h, _ = _dec_layer(cfg, prm, h, positions, ckv, mesh_ctx=mesh_ctx)
        if mesh_ctx is not None:
            h = mesh_ctx.shard_activations(h)
        return h

    body = jax.checkpoint(lambda c, prm: (layer(c, prm), None))
    h, _ = jax.lax.scan(body, h, params["dec_stack"], unroll=unroll)
    if last_logit_only:
        h = h[:, -1:]
    h = L.norm(cfg, params["ln_f"], h)
    return L.unembed(cfg, params["embed"], h, mesh_ctx)


def encdec_forward(cfg: ModelConfig, params, tokens, frames, *, mesh_ctx=None,
                   unroll: int = 1, last_logit_only: bool = False):
    enc_out = encode(cfg, params, frames, mesh_ctx=mesh_ctx, unroll=unroll)
    return decode_train(cfg, params, tokens, enc_out, mesh_ctx=mesh_ctx,
                        unroll=unroll, last_logit_only=last_logit_only)


# ---------------------------------------------------------------------------
# Incremental decode
# ---------------------------------------------------------------------------


def encdec_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                        enc_len: int) -> Dict:
    nL = cfg.n_layers
    kv = (nL, batch, max_seq, cfg.kv_heads, cfg.d_head)
    ckv = (nL, batch, enc_len, cfg.kv_heads, cfg.d_head)
    return {"k": kv, "v": kv, "ck": ckv, "cv": ckv}


def encdec_prefill_cache(cfg: ModelConfig, params, enc_out, batch: int,
                         max_seq: int):
    """Precompute per-layer cross KV from the encoder output; allocate the
    self-attention cache."""
    ck, cv = jax.vmap(lambda prm: L.make_cross_kv(prm, enc_out))(
        params["dec_stack"]["cross_kv"])
    nL = cfg.n_layers
    kv = jnp.zeros((nL, batch, max_seq, cfg.kv_heads, cfg.d_head), cfg.dtype)
    return {"k": kv, "v": kv, "ck": ck.astype(cfg.dtype),
            "cv": cv.astype(cfg.dtype)}


def encdec_decode_step(cfg: ModelConfig, params, cache, tokens, pos, *,
                       mesh_ctx=None, unroll: int = 1):
    """One decode token. tokens: (B,1); pos: scalar. Returns (logits, cache)."""
    B = tokens.shape[0]
    h = L.embed(cfg, params["embed"], tokens)
    h = h + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"].astype(h.dtype), pos, 1, axis=0)[None, 0:1]
    positions = jnp.full((1, 1), pos, jnp.int32)

    def layer(h, xs):
        prm, ck, cv, k, v = xs
        h, nc = _dec_layer(cfg, prm, h, positions, (ck, cv),
                           cache={"k": k, "v": v}, cache_pos=pos,
                           mesh_ctx=mesh_ctx)
        return h, (nc["k"], nc["v"])

    h, (nk, nv) = jax.lax.scan(
        layer, h,
        (params["dec_stack"], cache["ck"], cache["cv"], cache["k"],
         cache["v"]),
        unroll=unroll)
    h = L.norm(cfg, params["ln_f"], h)
    logits = L.unembed(cfg, params["embed"], h, mesh_ctx)
    return logits, {"k": nk, "v": nv, "ck": cache["ck"], "cv": cache["cv"]}

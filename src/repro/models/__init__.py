"""repro.models — the 10 assigned architectures as pure-JAX modules.

``api`` is the uniform entry point (spec/forward/loss/decode); ``common``
holds the ParamSpec system shared with sharding + checkpointing.
"""
from .api import (batch_shapes, decode_cache_shapes, decode_step, forward,
                  init_decode_cache, loss_fn, make_dummy_batch, model_spec)
from .common import (ModelConfig, ParamSpec, abstract_params, init_params,
                     param_count, tree_paths)

__all__ = [
    "ModelConfig", "ParamSpec", "abstract_params", "init_params",
    "param_count", "tree_paths", "batch_shapes", "decode_cache_shapes",
    "decode_step", "forward", "init_decode_cache", "loss_fn",
    "make_dummy_batch", "model_spec",
]

"""Decoder-only LM assembly for all pattern-based architectures.

A config's ``layer_pattern`` (e.g. ``"LG"`` for gemma2, ``"RRL"`` for
recurrentgemma, ``"GM"`` for llama4, ``"W"`` for rwkv6) defines a repeating
*unit*. Parameters of each unit are stacked with a leading repeat axis and
the forward pass is a ``lax.scan`` over repeats (compile-time O(1) in
depth); the remainder layers (n_layers % len(pattern)) form an explicit
tail. ``unroll`` is exposed because the roofline extractor compiles each
cell at unroll=1 and unroll=2 to recover exact per-layer HLO costs.

Layer kinds:
  G  global attention + dense MLP        L  local (windowed) attn + MLP
  M  global attention + MoE MLP          R  RG-LRU recurrent block + MLP
  W  RWKV6 time-mix + channel-mix
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .common import ModelConfig, ParamSpec, p
from .moe import moe, moe_spec
from .recurrent import (rglru_block, rglru_block_spec, rglru_state_shape,
                        rwkv_channel_mix, rwkv_channel_mix_spec,
                        rwkv_state_shape, rwkv_time_mix, rwkv_time_mix_spec)

# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


def _sublayer_spec(cfg: ModelConfig, kind: str) -> Dict:
    if kind in ("G", "L", "M"):
        d_ff = None
        if kind == "G" and cfg.n_experts and cfg.dense_d_ff:
            d_ff = cfg.dense_d_ff
        spec = {
            "ln1": L.norm_spec(cfg),
            "attn": L.attention_spec(cfg),
            "ln2": L.norm_spec(cfg),
        }
        if kind == "M":
            spec["moe"] = moe_spec(cfg)
        else:
            spec["mlp"] = L.mlp_spec(cfg, d_ff)
        if cfg.post_norms:
            spec["ln1_post"] = L.norm_spec(cfg)
            spec["ln2_post"] = L.norm_spec(cfg)
        return spec
    if kind == "R":
        return {
            "ln1": L.norm_spec(cfg),
            "rec": rglru_block_spec(cfg),
            "ln2": L.norm_spec(cfg),
            "mlp": L.mlp_spec(cfg),
        }
    if kind == "W":
        return {
            "ln1": L.norm_spec(cfg),
            "tm": rwkv_time_mix_spec(cfg),
            "ln2": L.norm_spec(cfg),
            "cm": rwkv_channel_mix_spec(cfg),
        }
    raise ValueError(f"unknown layer kind {kind!r}")


def _stack_spec(tree, n: int):
    def walk(t):
        if isinstance(t, dict):
            return {k: walk(v) for k, v in t.items()}
        s: ParamSpec = t
        return ParamSpec((n,) + s.shape, ("layer",) + s.axes, s.init,
                         s.scale, s.dtype)
    return walk(tree)


def unit_pattern(cfg: ModelConfig) -> Tuple[str, int, str]:
    """(pattern, n_repeats, tail): n_layers = n_repeats*len(pattern)+len(tail)."""
    pat = cfg.layer_pattern
    n_rep = cfg.n_layers // len(pat)
    tail = pat[: cfg.n_layers - n_rep * len(pat)]
    return pat, n_rep, tail


def lm_spec(cfg: ModelConfig) -> Dict:
    pat, n_rep, tail = unit_pattern(cfg)
    spec: Dict[str, Any] = {"embed": L.embed_spec(cfg)}
    unit = {f"{i}_{k}": _sublayer_spec(cfg, k) for i, k in enumerate(pat)}
    spec["stack"] = _stack_spec(unit, n_rep)
    for i, k in enumerate(tail):
        spec[f"tail_{i}_{k}"] = _sublayer_spec(cfg, k)
    spec["ln_f"] = L.norm_spec(cfg)
    if cfg.frontend == "patch_embed":
        spec["frontend_proj"] = p((cfg.frontend_dim, cfg.d_model),
                                  (None, "embed"), init="scaled")
    return spec


# ---------------------------------------------------------------------------
# Sublayer application
# ---------------------------------------------------------------------------


def _apply_sublayer(cfg: ModelConfig, kind: str, prm, h, *, positions,
                    mesh_ctx=None, cache=None, cache_pos=None,
                    cache_valid_len=None, paged=None, prefix_len: int = 0,
                    kv_shard=None):
    """One pattern-unit sublayer. Returns (h, new_cache)."""
    window = cfg.window if kind in ("L", "R") else None
    new_cache = None
    if mesh_ctx is not None:
        # FSDP: gather this sublayer's weights (in bf16) right before use —
        # sub-layer granularity halves the gathered working set vs gathering
        # the whole block (see MeshContext.constrain_tree).
        prm = mesh_ctx.constrain_tree(prm, _sublayer_spec(cfg, kind),
                                      fsdp=False)
    if kind in ("G", "L", "M"):
        x = L.norm(cfg, prm["ln1"], h)
        if cache is not None:
            attn_out, new_cache = L.attention(
                cfg, prm["attn"], x, positions=positions, window=window,
                cache=cache, cache_pos=cache_pos,
                cache_valid_len=cache_valid_len, paged=paged,
                mesh_ctx=mesh_ctx, kv_shard=kv_shard)
        else:
            attn_out, _ = L.attention(cfg, prm["attn"], x,
                                      positions=positions, window=window,
                                      prefix_len=prefix_len,
                                      mesh_ctx=mesh_ctx)
        if cfg.post_norms:
            attn_out = L.norm(cfg, prm["ln1_post"], attn_out)
        h = h + attn_out
        x = L.norm(cfg, prm["ln2"], h)
        if kind == "M":
            ff = moe(cfg, prm["moe"], x, mesh_ctx)
        else:
            ff = L.mlp(cfg, prm["mlp"], x, mesh_ctx)
        if cfg.post_norms:
            ff = L.norm(cfg, prm["ln2_post"], ff)
        h = h + ff
        return h, new_cache
    if kind == "R":
        x = L.norm(cfg, prm["ln1"], h)
        rec_out, new_cache = rglru_block(cfg, prm["rec"], x, state=cache,
                                         mesh_ctx=mesh_ctx)
        h = h + rec_out
        h = h + L.mlp(cfg, prm["mlp"], L.norm(cfg, prm["ln2"], h), mesh_ctx)
        return h, new_cache
    if kind == "W":
        x = L.norm(cfg, prm["ln1"], h)
        tm_out, tm_state = rwkv_time_mix(
            cfg, prm["tm"], x,
            state=None if cache is None else {"shift": cache["tm_shift"],
                                              "S": cache["S"]},
            mesh_ctx=mesh_ctx)
        h = h + tm_out
        x2 = L.norm(cfg, prm["ln2"], h)
        cm_out, cm_shift = rwkv_channel_mix(
            cfg, prm["cm"], x2,
            state=None if cache is None else cache["cm_shift"],
            mesh_ctx=mesh_ctx)
        h = h + cm_out
        if cache is not None or tm_state is not None:
            new_cache = {"tm_shift": tm_state["shift"], "S": tm_state["S"],
                         "cm_shift": cm_shift}
        return h, new_cache
    raise ValueError(kind)


def _unit_keys(pat: str) -> List[str]:
    return [f"{i}_{k}" for i, k in enumerate(pat)]


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def lm_forward(cfg: ModelConfig, params, tokens, *, mesh_ctx=None,
               patches=None, unroll: int = 1, last_logit_only: bool = False):
    """tokens: (B,S) int32. For VLM configs, ``patches`` (B,P,frontend_dim)
    are prepended as a bidirectional prefix. Returns logits (B,S',vocab)
    where S' includes the prefix for VLM. ``last_logit_only`` unembeds only
    the final position (serving prefill: a full (B,S,V) logit tensor at 32k
    is ~2.3 GiB/device that the sampler immediately discards)."""
    pat, n_rep, tail = unit_pattern(cfg)
    h = L.embed(cfg, params["embed"], tokens)
    prefix_len = 0
    if cfg.frontend == "patch_embed":
        assert patches is not None
        pe = (patches.astype(cfg.dtype) @ params["frontend_proj"])
        if cfg.embed_scale:
            pe = pe * jnp.asarray(np.sqrt(cfg.d_model), pe.dtype)
        h = jnp.concatenate([pe, h], axis=1)
        prefix_len = patches.shape[1]
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    if mesh_ctx is not None:
        h = mesh_ctx.shard_activations(h)
    def unit(h, prm_r):
        for key in _unit_keys(pat):
            kind = key.split("_")[1]
            h, _ = _apply_sublayer(cfg, kind, prm_r[key], h,
                                   positions=positions, mesh_ctx=mesh_ctx,
                                   prefix_len=prefix_len)
            if mesh_ctx is not None:
                h = mesh_ctx.shard_activations(h)
        return h

    if n_rep > 0:
        body = jax.checkpoint(lambda carry, prm_r: (unit(carry, prm_r), None))
        h, _ = jax.lax.scan(body, h, params["stack"], unroll=unroll)
    for i, k in enumerate(tail):
        h, _ = _apply_sublayer(cfg, k, params[f"tail_{i}_{k}"], h,
                               positions=positions, mesh_ctx=mesh_ctx,
                               prefix_len=prefix_len)
    if last_logit_only:
        h = h[:, -1:]
    h = L.norm(cfg, params["ln_f"], h)
    return L.unembed(cfg, params["embed"], h, mesh_ctx)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    """Abstract cache layout mirroring the param stacking: stacked leading
    repeat axis for the scanned unit, explicit entries for the tail."""
    pat, n_rep, tail = unit_pattern(cfg)

    def sub_shapes(kind: str):
        if kind == "G" or kind == "M":
            s = (batch, max_seq, cfg.kv_heads, cfg.d_head)
            return {"k": s, "v": s}
        if kind == "L":
            w = min(cfg.window or max_seq, max_seq)
            s = (batch, w, cfg.kv_heads, cfg.d_head)
            return {"k": s, "v": s}
        if kind == "R":
            return rglru_state_shape(cfg, batch)
        if kind == "W":
            return rwkv_state_shape(cfg, batch)
        raise ValueError(kind)

    out: Dict[str, Any] = {"stack": {}}
    for key in _unit_keys(pat):
        kind = key.split("_")[1]
        out["stack"][key] = jax.tree.map(lambda s: (n_rep,) + s,
                                         sub_shapes(kind),
                                         is_leaf=lambda x: isinstance(x, tuple))
    for i, k in enumerate(tail):
        out[f"tail_{i}_{k}"] = sub_shapes(k)
    return out


def _cache_dtype(cfg, path_leaf_shape):
    return cfg.dtype


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    shapes = cache_shapes(cfg, batch, max_seq)

    def mk(s):
        # recurrent fp32 state for numerical fidelity; KV in model dtype
        return jnp.zeros(s, cfg.dtype)

    return jax.tree.map(mk, shapes, is_leaf=lambda x: isinstance(x, tuple))


def lm_decode_step(cfg: ModelConfig, params, cache, tokens, pos, *,
                   mesh_ctx=None, unroll: int = 1, seq_lens=None,
                   paged_tables=None, kv_shard=None):
    """One decode step over a chunk of S tokens per row. tokens: (B,S);
    pos: scalar int32 (bulk decode, all rows aligned) or (B,) int32
    (continuous batching, per-slot start positions). For L layers the
    cache is a rolling window written at ``pos % window``.

    ``seq_lens`` (B,) gives the number of *real* tokens per row (rows are
    right-padded to the chunk width S); the logits returned are those of
    each row's last real token. Without ``seq_lens`` the last column is
    used (the S=1 decode semantics).

    Chunked prefill (S > 1 with per-slot ``pos``) writes each row's chunk
    at its own absolute offset — supported for G/M (global-attention)
    layers, whose cache slot order equals absolute position.

    Paged decode (``paged_tables`` (B, NW) int32): ``cache`` is the KV
    *pool* pytree (same structure, leaves (*lead, num_blocks, bt, KV, D));
    row b's chunk is written into — and attended out of — the pool rows
    its block table names. No per-slot contiguous KV exists. Requires
    per-slot ``pos`` and ``seq_lens``; G/M layers only.

    Returns (logits (B,1,vocab), new_cache).
    """
    pat, n_rep, tail = unit_pattern(cfg)
    B, S = tokens.shape
    per_slot = getattr(pos, "ndim", 0) == 1
    if S > 1 or paged_tables is not None:
        unsupported = set(pat + tail) - {"G", "M"}
        if unsupported:
            raise NotImplementedError(
                "chunked prefill and paged decode need absolute-position "
                f"KV caches; layer kinds {sorted(unsupported)} are "
                "rolling/recurrent")
    paged = None
    if paged_tables is not None:
        assert per_slot and seq_lens is not None, \
            "paged decode needs per-slot positions and seq_lens"
        paged = {"tables": paged_tables, "seq_lens": seq_lens}
    assert kv_shard is None or paged is not None, \
        "serve TP (kv_shard) only shards the paged data plane"
    h = L.embed(cfg, params["embed"], tokens)
    positions = (pos[:, None].astype(jnp.int32) + jnp.arange(S, dtype=jnp.int32)[None, :]
                 if per_slot
                 else jnp.full((1, 1), pos, jnp.int32) + jnp.arange(S, dtype=jnp.int32)[None, :])

    def sub_cache_pos(kind):
        if kind == "L":
            return pos % (cfg.window or 1)
        return pos

    def sub_valid_len(kind):
        # L caches are rolling windows: once wrapped, every slot is valid
        if kind == "L":
            return jnp.minimum(pos + 1, cfg.window or 1)
        return pos + 1

    # The stacked cache is threaded as a scan CARRY (not xs/ys): while-loop
    # carries alias their input/output buffers, so the multi-GiB KV cache
    # is updated in place. The xs/ys form kept TWO copies live (the read
    # stack until the last iteration plus the accumulating ys stack) —
    # observed +12.9 GiB/device on moonshot decode_32k (§Perf iteration 1).
    def unit(carry, prm_r):
        h, cache_stack, li = carry
        cache_r = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, li, 0,
                                                   keepdims=False),
            cache_stack)
        new_caches = {}
        for key in _unit_keys(pat):
            kind = key.split("_")[1]
            h, nc = _apply_sublayer(cfg, kind, prm_r[key], h,
                                    positions=positions, mesh_ctx=mesh_ctx,
                                    cache=cache_r[key],
                                    cache_pos=sub_cache_pos(kind),
                                    cache_valid_len=sub_valid_len(kind),
                                    paged=paged, kv_shard=kv_shard)
            new_caches[key] = nc
        cache_stack = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), li, 0),
            cache_stack, new_caches)
        return (h, cache_stack, li + 1), None

    if n_rep > 0:
        (h, new_stack, _), _ = jax.lax.scan(
            unit, (h, cache["stack"], jnp.int32(0)), params["stack"],
            unroll=unroll)
    else:
        new_stack = cache["stack"]
    new_cache = {"stack": new_stack}
    for i, k in enumerate(tail):
        key = f"tail_{i}_{k}"
        h, nc = _apply_sublayer(cfg, k, params[key], h, positions=positions,
                                mesh_ctx=mesh_ctx, cache=cache[key],
                                cache_pos=sub_cache_pos(k),
                                cache_valid_len=sub_valid_len(k),
                                paged=paged, kv_shard=kv_shard)
        new_cache[key] = nc
    if S > 1 or seq_lens is not None:
        # unembed only each row's last real token (padded rows are junk and
        # a full (B,S,V) logit tensor is wasted work)
        last = (jnp.maximum(seq_lens - 1, 0) if seq_lens is not None
                else jnp.full((B,), S - 1, jnp.int32))
        h = jnp.take_along_axis(h, last[:, None, None], axis=1)
    h = L.norm(cfg, params["ln_f"], h)
    logits = L.unembed(cfg, params["embed"], h, mesh_ctx)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, logits, targets, mask=None):
    """Next-token cross entropy; fp32 log-softmax. targets already shifted."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

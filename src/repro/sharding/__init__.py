"""repro.sharding — logical-axis partition rules (DP/FSDP/TP/EP/SP)."""
from .rules import LOGICAL_RULES, MeshContext, local_context

__all__ = ["LOGICAL_RULES", "MeshContext", "local_context"]

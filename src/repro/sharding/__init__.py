"""repro.sharding — logical-axis partition rules (DP/FSDP/TP/EP/SP)."""
from .rules import (LOGICAL_RULES, KVShardCtx, MeshContext, local_context,
                    serve_tp_context)

__all__ = ["LOGICAL_RULES", "KVShardCtx", "MeshContext", "local_context",
           "serve_tp_context"]

"""Logical-axis → PartitionSpec rules for the production mesh.

Parameters carry logical axis names (``ParamSpec.axes``); this module maps
them onto the physical mesh:

* TP axes (``heads``, ``kv_heads``, ``ff``, ``vocab``, ``experts``,
  ``rnn``, ``rnn_blocks``) shard over ``model``.
* ``embed`` shards over the FSDP axes (``("pod","data")`` multi-pod,
  ``("data",)`` single-pod) — ZeRO-3: all-gather on use, reduce-scatter on
  grad, both inserted by XLA SPMD from the shardings.
* ``layer`` (the scan-stack axis) stays replicated.

Every assignment is divisibility-checked against the mesh and each mesh
axis is used at most once per tensor; dims that do not divide are left
replicated (XLA handles the rest). This keeps the same rule table valid
from the 4-device CI mesh to the 512-chip production mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.common import ModelConfig, ParamSpec

# logical axis -> candidate physical axis group, in priority order
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "rnn": ("model",),
    "rnn_blocks": ("model",),
    "embed": ("fsdp",),
    "head_dim": (),
    "layer": (),
}


@dataclass
class MeshContext:
    """Everything the model/step code needs to know about the mesh."""

    mesh: Optional[Mesh]
    data_axes: Tuple[str, ...] = ("data",)     # batch / FSDP axes
    model_axis: str = "model"
    seq_shard: bool = True                     # SP: shard seq dim over model
    fsdp_params: bool = True                   # ZeRO-3 parameter sharding

    # ------------------------------------------------------------------ sizes
    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name] if self.mesh else 1

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.axis_size(a) for a in self.data_axes]))

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.model_axis)

    def _expand(self, group: str) -> Tuple[str, ...]:
        if group == "fsdp":
            return self.data_axes if self.fsdp_params else ()
        return (group,)

    # ------------------------------------------------------------- param spec
    def param_pspec(self, spec: ParamSpec, fsdp: Optional[bool] = None) -> P:
        """PartitionSpec for one parameter from its logical axes.
        ``fsdp=False`` drops the FSDP axes (the *gathered* per-layer layout
        a weight takes while its layer executes)."""
        used: set = set()
        out = []
        fsdp_on = self.fsdp_params if fsdp is None else fsdp
        for dim, logical in zip(spec.shape, spec.axes):
            assigned: Any = None
            if logical is not None:
                for group in LOGICAL_RULES.get(logical, ()):
                    axes = (self.data_axes if fsdp_on else ()) \
                        if group == "fsdp" else (group,)
                    if not axes or any(a in used for a in axes):
                        continue
                    size = int(np.prod([self.axis_size(a) for a in axes]))
                    if size > 1 and dim % size == 0:
                        assigned = axes if len(axes) > 1 else axes[0]
                        used.update(axes)
                        break
            out.append(assigned)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def param_sharding(self, spec: ParamSpec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.param_pspec(spec))

    def constrain_tree(self, tree, spec_tree, fsdp: Optional[bool] = None):
        """Pin a (possibly per-layer-sliced) param tree to its rule-derived
        shardings. Used INSIDE scan bodies with ``fsdp=False``: the
        constraint makes SPMD all-gather each layer's weights in their
        stored dtype (bf16) *before* any CPU-backend f32 upcast — without
        it, XLA converts-then-gathers, doubling both wire bytes and the
        per-layer gathered-weight working set. The transpose constrains the
        cotangent identically, keeping weight grads from materializing
        fully replicated."""
        if self.mesh is None:
            return tree
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(
                t, NamedSharding(self.mesh, self.param_pspec(s, fsdp=fsdp))),
            tree, spec_tree,
            is_leaf=lambda x: isinstance(x, ParamSpec))

    # -------------------------------------------------------------- batch dims
    def _dim_axes(self, dim: int, candidates: Sequence[str],
                  used: set) -> Any:
        """Largest prefix of ``candidates`` whose product divides ``dim``."""
        picked = []
        for a in candidates:
            if a in used:
                break
            nxt = picked + [a]
            size = int(np.prod([self.axis_size(x) for x in nxt]))
            if dim % size != 0:
                break
            picked = nxt
        if not picked:
            return None
        used.update(picked)
        return tuple(picked) if len(picked) > 1 else picked[0]

    def batch_pspec(self, shape: Tuple[int, ...]) -> P:
        """(B, S, ...) activations / tokens: B over data axes; S over model
        (sequence parallelism) when enabled and divisible."""
        used: set = set()
        b = self._dim_axes(shape[0], self.data_axes, used)
        rest: list = [None] * (len(shape) - 1)
        if len(shape) >= 2 and self.seq_shard:
            s = self._dim_axes(shape[1], (self.model_axis,), used)
            rest[0] = s
        return P(b, *rest)

    def batch_sharding(self, shape, dtype=jnp.int32) -> jax.ShapeDtypeStruct:
        sh = (NamedSharding(self.mesh, self.batch_pspec(shape))
              if self.mesh else None)
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)

    # ------------------------------------------------------------ activations
    def constrain_dims(self, x: jax.Array, dims) -> jax.Array:
        """Megatron-SP style explicit layout: ``dims`` is one axis-group
        candidate (axis name, tuple of names, or None) per tensor dim;
        non-divisible dims fall back to replicated. Examples:
          MLP intermediate (B,S,2,f): (data_axes, None, None, model)
          q after projection (B,S,H,D): (data_axes, None, model, None)
        """
        if self.mesh is None:
            return x
        used: set = set()
        out = []
        for size, cand in zip(x.shape, dims):
            if cand is None:
                out.append(None)
                continue
            cands = cand if isinstance(cand, tuple) else (cand,)
            out.append(self._dim_axes(size, cands, used))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*out)))

    def gather_seq(self, x: jax.Array) -> jax.Array:
        """Enter a TP region: batch stays on the data axes, sequence (and
        everything else) gathered — the SP all-gather on layer entry."""
        if self.mesh is None:
            return x
        return self.constrain_dims(x, (self.data_axes,)
                                   + (None,) * (x.ndim - 1))

    def shard_activations(self, h: jax.Array) -> jax.Array:
        """Residual-stream constraint: (B, S, d) -> batch over data axes,
        seq over model (SP). Non-divisible dims stay replicated."""
        if self.mesh is None:
            return h
        return jax.lax.with_sharding_constraint(
            h, NamedSharding(self.mesh, self.batch_pspec(h.shape)))

    # ------------------------------------------------------------- cache spec
    def cache_pspec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        """Decode-cache leaves. Layout conventions (models/api):
        KV: (..., B, S, KV_heads, D); recurrent h: (..., B, W);
        rwkv S: (..., B, H, N, N); shifts/conv keep B only.
        Leading stacked ``layer`` dims are detected by path containing
        'stack' or encdec stacked caches (k/v/ck/cv with ndim 5).
        """
        name = path[-1]
        used: set = set()
        n_lead = 0
        if any(p == "stack" for p in path[:-1]):
            n_lead = 1
        elif name in ("k", "v", "ck", "cv") and len(shape) == 5:
            n_lead = 1  # encdec stacked (nL, B, S, KV, D)
        dims: list = [None] * len(shape)
        bdim = n_lead
        if name in ("k", "v", "ck", "cv"):
            b, s, kv = shape[bdim], shape[bdim + 1], shape[bdim + 2]
            dims[bdim] = self._dim_axes(b, self.data_axes, used)
            if dims[bdim] is None or (
                    isinstance(dims[bdim], str) and len(self.data_axes) > 1):
                # long-context small-batch: spread the sequence dim instead
                leftover = [a for a in self.data_axes if a not in used]
                dims[bdim + 1] = self._dim_axes(s, leftover, used)
            dims[bdim + 2] = self._dim_axes(kv, (self.model_axis,), used)
            if dims[bdim + 2] is None and dims[bdim + 1] is None:
                # few KV heads (MQA/whisper): spread sequence over model
                dims[bdim + 1] = self._dim_axes(s, (self.model_axis,), used)
        elif name == "h":                       # rg-lru state (..., B, W)
            dims[bdim] = self._dim_axes(shape[bdim], self.data_axes, used)
            dims[-1] = self._dim_axes(shape[-1], (self.model_axis,), used)
        elif name == "conv":                    # (..., B, K-1, W)
            dims[bdim] = self._dim_axes(shape[bdim], self.data_axes, used)
            dims[-1] = self._dim_axes(shape[-1], (self.model_axis,), used)
        elif name == "S":                       # rwkv (..., B, H, N, N)
            dims[bdim] = self._dim_axes(shape[bdim], self.data_axes, used)
            dims[bdim + 1] = self._dim_axes(shape[bdim + 1],
                                            (self.model_axis,), used)
        else:                                   # shifts: (..., B, d)
            dims[bdim] = self._dim_axes(shape[bdim], self.data_axes, used)
        while dims and dims[-1] is None:
            dims.pop()
        return P(*dims)

    def cache_sharding(self, path, shape, dtype) -> jax.ShapeDtypeStruct:
        sh = (NamedSharding(self.mesh, self.cache_pspec(path, shape))
              if self.mesh else None)
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)

    # ---------------------------------------------------------------- scalars
    def replicated(self) -> Optional[NamedSharding]:
        return NamedSharding(self.mesh, P()) if self.mesh else None


def local_context() -> MeshContext:
    """Single-device context (smoke tests): no mesh, no constraints."""
    return MeshContext(mesh=None, data_axes=(), seq_shard=False)


# ---------------------------------------------------------------------------
# Serve-plane tensor parallelism (PR 7)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KVShardCtx:
    """Tensor parallelism for the *paged serve plane*: a 1-D mesh whose
    ``axis`` shards the KV-head dimension of every pool leaf (and the
    matching q/k/v head slices inside the attention shard_map).

    Deliberately NOT a ``MeshContext``: serving wants attention-only
    sharding with replicated parameters — the full rule table would drag
    in FSDP gathers, Megatron MLP splits, and vocab-parallel logits, none
    of which pay off at decode batch sizes. Block tables, refcounts, and
    every host-side store structure stay device-invariant: a pool row
    index means the same block on every shard, so the policy/tiering/
    coordination layers never see the mesh.

    Frozen (and ``Mesh`` is hashable), so a ctx can key the engine's
    shared-jit ``lru_cache`` directly.
    """

    mesh: Mesh
    axis: str = "model"

    @property
    def tp(self) -> int:
        return int(self.mesh.shape[self.axis])

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def head_spec(self, ndim: int, head_axis: int) -> P:
        """PartitionSpec sharding dim ``head_axis`` of an ndim tensor."""
        dims: list = [None] * ndim
        dims[head_axis] = self.axis
        return P(*dims)

    def pool_sharding(self, ndim: int) -> NamedSharding:
        """Sharding for a pool leaf (*lead, nb, bt, KV, D) — or a stacked
        row batch (n, *lead, bt, KV, D): KV sits at dim -2 in both."""
        return NamedSharding(self.mesh, self.head_spec(ndim, ndim - 2))

    def validate(self, cfg) -> None:
        if cfg.kv_heads % self.tp:
            raise ValueError(
                f"tensor parallelism tp={self.tp} needs the KV-head count "
                f"to divide evenly; {cfg.arch} has kv_heads={cfg.kv_heads}")


def serve_tp_context(tp: int, axis: str = "model") -> KVShardCtx:
    """1-D serve mesh over the first ``tp`` local devices. CPU-testable:
    XLA_FLAGS=--xla_force_host_platform_device_count=N fakes N devices."""
    devs = jax.devices()
    if len(devs) < tp:
        raise ValueError(
            f"--tp {tp} needs {tp} devices but only {len(devs)} are "
            "visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={tp} (before jax "
            "initializes)")
    return KVShardCtx(mesh=Mesh(np.asarray(devs[:tp]), (axis,)), axis=axis)

"""repro.sim — discrete-event cluster simulator driving the real cache
policy code with modeled time (the quantitative vehicle for the paper's
Figs. 3 and 5–7 on a single CPU container)."""
from .cluster import ClusterSim, HardwareModel, SimResult
from .workloads import (bursty_arrivals, coalesce_job, diurnal_arrivals,
                        multi_tenant_zip, poisson_arrivals,
                        zip_access_trace, zip_job)

__all__ = ["ClusterSim", "HardwareModel", "SimResult", "coalesce_job",
           "multi_tenant_zip", "zip_access_trace", "zip_job",
           "poisson_arrivals", "bursty_arrivals", "diurnal_arrivals"]

"""Discrete-event cluster simulator for cache-policy evaluation.

Models the paper's EC2 deployment (§IV): ``n_workers`` machines, each with a
bounded RDD cache, a disk tier, a fixed number of task slots, and
disk/memory/network bandwidths. Jobs are ``JobDAG``s; the scheduler is
locality-aware and round-robins across tenants (FIFO within a job).

Task duration = scheduling overhead + max-over-inputs(fetch time) + compute:
the *max* is the paper's all-or-nothing bottleneck — one cold peer hides
every warm one.

The simulator drives the same ``CacheManager``/``DagState``/policy code that
the real data pipeline uses; only time is simulated. Victim selection runs
on each manager's ``EvictionIndex`` (O(log n) pops) over that worker's OWN
``DagState`` replica, held by its ``PeerTracker``: every piece of
cross-worker state — peer profiles at job submission, materialize/load
status, eviction broadcasts — flows through the shared ``MessageBus``, so
``SimResult.messages`` is exactly what the coordination protocol actually
sent (no hand-maintained counters anywhere in this module). Replicas are
verified bit-identical to the driver's authoritative state (and to a
from-scratch oracle) at the end of every ``run``.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import (Belady, CacheManager, CacheMetrics, DagState, JobDAG,
                    MessageBus, MessageStats, PeerTracker, PeerTrackerMaster,
                    TaskSpec, make_policy)
from ..faults import FaultInjector, FaultPlan
from ..obs.trace import TID_BUS as _TID_BUS


@dataclass
class HardwareModel:
    """Per-worker hardware. Defaults calibrated to the paper's m4.large
    fleet (2 vCPU / 8 GB, EBS magnetic, direct I/O): see
    benchmarks/fig5_makespan.py for the calibration note."""

    cache_bytes: int = 5_300 * 2 ** 20 // 10      # per-worker share, set by runner
    disk_bw: float = 50e6                         # B/s  (direct I/O, no page cache)
    mem_bw: float = 10e9                          # B/s
    net_bw: float = 56e6                          # B/s  (m4.large "moderate")
    slots: int = 2                                # task slots (2 vCPUs)
    task_overhead: float = 0.08                   # s, Spark launch+sched delay
    compute_bw: float = 200e6                     # B/s processed by task code
    disk_queue: bool = False                      # True: serialize the volume;
                                                  # False: parallel streams at
                                                  # per-stream disk_bw (EBS-like)
    msg_latency: float = 0.0                      # s per bus hop: the driver
                                                  # learns of a task's finish
                                                  # one status-report hop after
                                                  # it happens, so dependents
                                                  # launch that much later.
                                                  # 0 = instantaneous bus
                                                  # (bit-identical to pre-PR-4
                                                  # results)


@dataclass
class SimResult:
    makespan: float
    metrics: CacheMetrics
    messages: MessageStats
    per_job_finish: Dict[str, float] = field(default_factory=dict)
    task_runtimes: Dict[str, float] = field(default_factory=dict)

    def as_dict(self):
        return {
            "makespan": self.makespan,
            **self.metrics.as_dict(),
            "messages": self.messages.as_dict(),
        }


class ClusterSim:
    def __init__(self, n_workers: int, hw: HardwareModel, policy: str = "lerc",
                 policy_kwargs: Optional[dict] = None,
                 cache_outputs: bool = True,
                 trace=None, stats_level: str = "full",
                 faults=None) -> None:
        self.n_workers = n_workers
        self.hw = hw
        # deterministic fault injection (repro.faults): worker crashes fire
        # as simulator events at their plan times; bus faults ride the
        # shared MessageBus. None = healthy cluster, bit-identical to a sim
        # built without the parameter.
        if isinstance(faults, FaultPlan):
            faults = faults.injector()
        self.faults: Optional[FaultInjector] = faults
        self._faulted = False                     # any crash fired yet?
        self.worker_crashes_fired = 0
        # obs: an attached TraceRecorder (None = zero-overhead off). Tasks
        # are retrospective X events on the VIRTUAL clock — pid 0 with one
        # lane per worker; the bus is pid 1.
        self.trace = trace
        # the coordination plane: driver-side master (authoritative DAG +
        # state) and one worker-side tracker per machine, each holding its
        # own DagState replica fed only by bus messages
        self.bus = MessageBus(record_log=False, stats_level=stats_level)
        self.bus.faults = self.faults
        if trace is not None:
            trace.label(0, "sim")
            for w in range(n_workers):
                trace.label(0, "sim", tid=w, tname=f"worker{w}")
            trace.label(1, "bus", tid=_TID_BUS)
            self.bus.trace = trace
            self.bus.trace_pid = 1
        self.trackers = [PeerTracker(w, self.bus) for w in range(n_workers)]
        self.master = PeerTrackerMaster(self.bus, n_workers)
        self.dag = self.master.dag        # driver's view (scheduling)
        self.state = self.master.state
        self.metrics = CacheMetrics()
        self.cache_outputs = cache_outputs
        self.policy_name = policy
        self._policies = []
        self.managers: List[CacheManager] = []
        for w in range(n_workers):
            pol = make_policy(policy, **(policy_kwargs or {}))
            self._policies.append(pol)
            self.managers.append(CacheManager(
                capacity=hw.cache_bytes, policy=pol,
                state=self.trackers[w].state, metrics=self.metrics,
                on_evict=self._make_evict_hook(w)))
        # protocol level is a cluster-wide deployment choice derived from
        # the policy: DAG-oblivious policies ship no peer profiles, and
        # only completeness-aware ones run the eviction report protocol
        self._distribute_profiles = self._policies[0].uses_dag
        self._coordinated = self._policies[0].uses_completeness
        self.home: Dict[str, int] = {}            # block -> worker
        self._outputs_not_cached: set = set()
        self._done: set = set()                   # executed tasks, across runs
        # per-worker disk is a serialized resource (the m4.large EBS volume):
        # concurrent readers queue behind each other
        self._disk_free = [0.0] * n_workers

    @property
    def messages(self) -> MessageStats:
        """All message accounting comes from actual bus traffic."""
        return self.bus.stats

    # ------------------------------------------------------------- protocol
    def _make_evict_hook(self, worker: int):
        """The worker's cache manager applied an eviction to this worker's
        replica; run the protocol: report to the master iff the eviction
        broke a complete peer group (master then broadcasts, keeping every
        other replica's labels current), and always ship the legacy
        block-status update."""
        def hook(block: str, flipped_groups: List[str]) -> None:
            tracker = self.trackers[worker]
            if self._coordinated:
                tracker.report_eviction(block, flipped_groups)
            tracker.report_status("evicted", block)
        return hook

    # ------------------------------------------------------------ job intake
    def submit(self, job: JobDAG, output_not_cached: Sequence[str] = ()) -> None:
        for b in job.blocks.values():
            if b.id not in self.dag.blocks:
                self.home[b.id] = (b.preferred_worker
                                   if b.preferred_worker is not None
                                   else len(self.home) % self.n_workers)
        self._outputs_not_cached.update(output_not_cached)
        # merge into the authoritative DAG (incremental task arrival — no
        # rebuild) and broadcast the delta as the peer profile
        self.master.submit_job(job, broadcast=self._distribute_profiles)

    # ---------------------------------------------------------------- timing
    def _disk_io(self, worker: int, nbytes: int, clock: float) -> float:
        """Seconds until a disk transfer of ``nbytes`` started at ``clock``
        completes, serializing behind in-flight transfers on that worker's
        volume (direct I/O: no page cache, §IV)."""
        if not self.hw.disk_queue:
            return nbytes / self.hw.disk_bw
        start = max(self._disk_free[worker], clock)
        self._disk_free[worker] = start + nbytes / self.hw.disk_bw
        return self._disk_free[worker] - clock

    def _fetch_time(self, block: str, on_worker: int, clock: float
                    ) -> Tuple[float, bool]:
        """(seconds, was_cache_hit) to fetch a materialized block."""
        size = self.dag.blocks[block].size
        h = self.home[block]
        mgr = self.managers[h]
        if mgr.in_memory(block):
            t = size / self.hw.mem_bw
            if h != on_worker:
                t += size / self.hw.net_bw
            self.metrics.mem_bytes_read += size
            return t, True
        # on disk at its home worker
        t = self._disk_io(h, size, clock)
        if h != on_worker:
            t += size / self.hw.net_bw
        self.metrics.disk_bytes_read += size
        return t, False

    def _source_read_time(self, block: str, worker: int, clock: float) -> float:
        """Initial materialization from stable storage (not a cache access)."""
        return self._disk_io(worker, self.dag.blocks[block].size, clock)

    # -------------------------------------------------------------- schedule
    def _unmet(self, task: TaskSpec) -> int:
        """Inputs not yet materialized. Raw source blocks (no producer) live
        on stable storage and are always available."""
        return sum(1 for b in task.inputs
                   if b in self.dag.producer and b not in self.state.materialized)

    def _pick_worker(self, task: TaskSpec, free_slots: List[int]) -> int:
        """Locality: the eligible worker holding the most input bytes."""
        eligible = [w for w in range(self.n_workers) if free_slots[w] > 0]
        if not eligible:
            raise RuntimeError("no free slot")

        def local_bytes(w: int) -> int:
            return sum(self.dag.blocks[b].size for b in task.inputs
                       if self.home.get(b) == w)

        return max(eligible, key=lambda w: (local_bytes(w), -w))

    def run(self, belady_trace: Optional[List[str]] = None,
            stages: Optional[set] = None) -> SimResult:
        """Run all currently-runnable tasks to completion.

        ``stages``: if given, only tasks whose ``stage`` is in the set are
        executed this call — used to separate the (unmeasured) ingest phase
        from the measured compute phase, as in the paper's §IV setup where
        the input files are partitioned and stored before the zip jobs are
        timed. The cache policy sees the *full* DAG throughout (reference
        counts are known from job submission, as in Spark's lazy plan).
        Each call measures its own makespan from t=0.
        """
        if belady_trace is not None:
            for pol in self._policies:
                if isinstance(pol, Belady):
                    pol.set_trace(list(belady_trace))
        clock = 0.0
        self._disk_free = [0.0] * self.n_workers
        free_slots = [self.hw.slots] * self.n_workers
        done: set = self._done
        # (t, seq, kind, task, worker): "finish" = a task completes on a
        # worker; "ready" = the driver *learns* a task became runnable
        # (its last producer's status report arrived, hw.msg_latency later)
        events: List[Tuple[float, int, str, str, int]] = []
        seq = itertools.count()
        per_job_finish: Dict[str, float] = {}
        task_runtimes: Dict[str, float] = {}
        # makespan is charged by task completions only: a crash event (or a
        # delayed bus flush) after the last finish must not extend it
        makespan = 0.0
        # tid -> (worker, finish-event seq): tasks currently executing. A
        # crash aborts the victims by seq, so their already-queued finish
        # events become stale no-ops — the recompute run pushes fresh ones.
        inflight: Dict[str, Tuple[int, int]] = {}
        aborted: set = set()
        if self.faults is not None:
            for i, (t, w) in enumerate(self.faults.plan.worker_crashes):
                if (0 <= int(w) < self.n_workers
                        and self.faults.claim(("worker", i))):
                    heapq.heappush(events, (float(t), next(seq),
                                            "crash", "", int(w)))

        def runnable(t: TaskSpec) -> bool:
            return (t.id not in done
                    and (stages is None or t.stage in stages))

        # incremental readiness: unmet-producer counts per task
        unmet: Dict[str, int] = {t.id: self._unmet(t)
                                 for t in self.dag.tasks.values()
                                 if runnable(t)}
        ready_by_job: Dict[str, List[TaskSpec]] = {}
        for t in sorted(self.dag.tasks.values(), key=lambda t: t.id):
            if runnable(t) and unmet[t.id] == 0:
                ready_by_job.setdefault(t.job, []).append(t)
        # multi-tenant fairness: round-robin across jobs
        job_order = sorted(self.dag.jobs)
        rr = itertools.cycle(job_order)

        def try_schedule() -> None:
            while any(free_slots) and any(ready_by_job.values()):
                job = next(rr)
                if not ready_by_job.get(job):
                    continue
                task = ready_by_job[job].pop(0)
                worker = self._pick_worker(task, free_slots)
                free_slots[worker] -= 1
                dur = self._task_duration(task, worker, clock)
                task_runtimes[task.id] = dur
                if self.trace is not None:
                    # sim time is in seconds; the recorder's virtual clock
                    # is milliseconds (1 vt unit -> 1ms on export)
                    self.trace.complete(
                        task.id, "task", 0, worker,
                        vt=clock * 1e3, dur=dur * 1e3,
                        args={"job": task.job, "worker": worker})
                eseq = next(seq)
                inflight[task.id] = (worker, eseq)
                heapq.heappush(events, (clock + dur, eseq, "finish",
                                        task.id, worker))

        try_schedule()
        while events:
            clock, eseq, kind, tid, worker = heapq.heappop(events)
            if self.bus.faults is not None and self.bus._delayed:
                self.bus.flush_delayed(clock)
            if kind == "ready":
                # the completion status report reached the driver: the
                # dependent task is now visible to the scheduler
                t = self.dag.tasks[tid]
                if self._faulted and (
                        tid in done or tid in inflight
                        or unmet.get(tid, 1) != 0
                        or any(x.id == tid
                               for x in ready_by_job.get(t.job, ()))):
                    # stale: a crash-time readiness rebuild already re-listed
                    # (or re-ran) this task before its report arrived
                    continue
                ready_by_job.setdefault(t.job, []).append(t)
                try_schedule()
                continue
            if kind == "crash":
                self._handle_crash(worker, clock, done, free_slots, inflight,
                                   aborted, unmet, ready_by_job,
                                   task_runtimes, runnable)
                try_schedule()
                continue
            if eseq in aborted:
                # finish event of a task killed by a crash mid-flight: the
                # worker restarted, the slot accounting was reset there
                aborted.discard(eseq)
                continue
            task = self.dag.tasks[tid]
            done.add(tid)
            inflight.pop(tid, None)
            makespan = clock
            free_slots[worker] += 1
            # materialize output at this worker: the owning manager applies
            # the local event to its replica, then the worker reports it
            # over the legacy status channel (master folds it into the
            # authoritative state and relays to every other replica)
            out = task.output
            self.home.setdefault(out, worker)
            home = self.home[out]
            mgr = self.managers[home]
            if self.cache_outputs and out not in self._outputs_not_cached:
                mgr.insert(out, self.dag.blocks[out].size)
                self.trackers[home].report_status(
                    "materialized" if mgr.in_memory(out)
                    else "materialized_disk", out)
            else:
                mgr.disk.put(out, self.dag.blocks[out].size)
                mgr.state.on_materialized(out, into_cache=False)
                self.trackers[home].report_status("materialized_disk", out)
            per_job_finish[task.job] = clock
            for cons in self.dag.consumers.get(out, []):
                if cons not in unmet:
                    continue
                unmet[cons] -= 1
                if unmet[cons] == 0:
                    if self.hw.msg_latency > 0:
                        # the scheduler only sees the completion once the
                        # worker's status report has crossed the bus
                        heapq.heappush(events,
                                       (clock + self.hw.msg_latency,
                                        next(seq), "ready", cons, -1))
                    else:
                        ready_by_job.setdefault(
                            self.dag.tasks[cons].job, []) \
                            .append(self.dag.tasks[cons])
            try_schedule()

        if self.bus.faults is not None:
            # deliver any still-delayed traffic, then reconverge replicas
            # that sit behind dropped status messages before the coherence
            # check — anti-entropy is the documented repair path for drops
            self.bus.flush_delayed(float("inf"))
            if self.bus.stats.dropped:
                self.resync_replicas()
        self.verify_replicas()
        self.metrics.check_attribution()
        return SimResult(makespan=makespan, metrics=self.metrics,
                         messages=self.messages, per_job_finish=per_job_finish,
                         task_runtimes=task_runtimes)

    # --------------------------------------------------------------- faults
    def _handle_crash(self, worker: int, clock: float, done: set,
                      free_slots: List[int], inflight, aborted: set,
                      unmet, ready_by_job, task_runtimes, runnable) -> None:
        """A worker crashed (and immediately restarts empty, Spark's
        executor-loss model): its running tasks die, every block it cached
        — memory and local disk — is gone, and the driver relays the loss
        over the status channel so all replicas resurrect the producers'
        references (``DagState.on_lost``). Dependent recompute is then just
        ordinary scheduling over the repaired readiness view, charged to
        the makespan like any other work."""
        self._faulted = True
        self.worker_crashes_fired += 1
        if self.faults is not None:
            self.faults.count("fault.worker_crash")
        if self.trace is not None:
            self.trace.instant("fault.worker_crash", "fault", 0, worker,
                               vt=clock * 1e3)
        # running tasks on the victim die: their queued finish events are
        # stale; drop them by event seq (a recompute may re-run the same
        # task id, whose fresh finish event must NOT be discarded)
        for t_id, (w, eseq) in list(inflight.items()):
            if w == worker:
                aborted.add(eseq)
                del inflight[t_id]
                task_runtimes.pop(t_id, None)
        # both tiers of the victim's block store are lost
        mgr = self.managers[worker]
        lost = sorted(set(mgr.mem.blocks) | set(mgr.disk.blocks))
        for b in lost:
            if b in mgr.mem:
                mgr.mem.drop(b)
            mgr.disk.drop(b)
            mgr.index.discard(b)
            mgr.policy.on_remove(b)
        free_slots[worker] = self.hw.slots      # restarted executor
        # driver-detected loss, relayed like a silent eviction: every
        # replica (including the restarted worker's) folds on_lost —
        # un-materialize, resurrect the producer's reference counts
        resurrected = []
        for b in lost:
            self.master.status_update("lost", b)
            self.home.pop(b, None)
            p = self.dag.producer.get(b)
            if p is not None and p in done:
                done.discard(p)
                resurrected.append(p)
        if self.faults is not None:
            self.faults.count("recover.lost_blocks", len(lost))
            self.faults.count("recover.recompute", len(resurrected))
        if self.trace is not None:
            self.trace.instant("recover.lineage", "fault", 0, worker,
                               vt=clock * 1e3,
                               args={"lost_blocks": len(lost),
                                     "recompute_tasks": len(resurrected)})
        # rebuild the scheduler's readiness view from the repaired state:
        # aborted + resurrected tasks become pending again, everything
        # in-flight elsewhere stays where it is (in place — these dicts
        # are closed over by the run() loop)
        unmet.clear()
        for t in self.dag.tasks.values():
            if runnable(t) and t.id not in inflight:
                unmet[t.id] = self._unmet(t)
        for lst in ready_by_job.values():
            lst.clear()
        for t in sorted(self.dag.tasks.values(), key=lambda t: t.id):
            if runnable(t) and t.id not in inflight and unmet[t.id] == 0:
                ready_by_job.setdefault(t.job, []).append(t)

    def resync_replicas(self) -> None:
        """Anti-entropy: every tracker pulls the master's authoritative
        snapshot (reliable RPC, exempt from injection). Used after runs
        whose status traffic was lossy."""
        for tr in self.trackers:
            tr.request_resync(include_dag=self._distribute_profiles)

    # ------------------------------------------------------------ invariants
    def verify_replicas(self) -> None:
        """Every worker replica must agree with the driver's authoritative
        state, and the driver's incremental counters with a from-scratch
        rebuild (the paper's Definitions computed directly). Cheap —
        O(blocks + tasks) — and run at the end of every ``run`` so the
        whole sim test suite doubles as a coherence proof."""
        ms = self.master.state
        oracle = DagState(self.master.dag,
                          materialized=set(ms.materialized),
                          cached=set(ms.cached),
                          done_tasks=set(ms.done_tasks))
        blocks = self.master.dag.blocks
        assert all(ms.ref_count.get(b, 0) == oracle.ref_count.get(b, 0)
                   for b in blocks), "driver ref counts diverge from oracle"
        assert all(ms.eff_ref_count.get(b, 0) == oracle.eff_ref_count.get(b, 0)
                   for b in blocks), "driver eff counts diverge from oracle"
        for tr in self.trackers:
            st = tr.state
            assert st.cached == ms.cached, f"{tr.name}: cached set diverged"
            assert st.materialized == ms.materialized, \
                f"{tr.name}: materialized set diverged"
            if not self._distribute_profiles:
                continue      # no peer profile -> replica has no DAG view
            assert st.done_tasks == ms.done_tasks, \
                f"{tr.name}: done tasks diverged"
            assert all(st.ref_count.get(b, 0) == ms.ref_count.get(b, 0)
                       for b in blocks), f"{tr.name}: ref counts diverged"
            assert all(st.eff_ref_count.get(b, 0) == ms.eff_ref_count.get(b, 0)
                       for b in blocks), f"{tr.name}: eff counts diverged"

    # ----------------------------------------------------------- task timing
    def _task_duration(self, task: TaskSpec, worker: int, clock: float) -> float:
        hw = self.hw
        dur = hw.task_overhead + task.compute_cost
        cacheable_inputs = [b for b in task.inputs if b in self.dag.producer]
        if not cacheable_inputs:
            # pure source/load task: reads external storage via the disk
            dur += sum(self._source_read_time(b, worker, clock)
                       for b in task.inputs)
            dur += sum(self.dag.blocks[b].size
                       for b in task.inputs) / hw.compute_bw
            return dur
        # Def. 1 effectiveness, judged before any access mutates state
        all_cached = all(self.managers[self.home[b]].in_memory(b)
                         for b in cacheable_inputs)
        # ineffective-hit attribution: the first blocking peer's location
        # (a disk-resident blocker makes the group one load from complete;
        # an absent one costs a recompute)
        cause = None
        if not all_cached:
            blocker = next(b for b in cacheable_inputs
                           if not self.managers[self.home[b]].in_memory(b))
            cause = ("disk"
                     if blocker in self.managers[self.home[blocker]].disk
                     else "never_cached")
        fetch = 0.0
        for b in cacheable_inputs:
            t, hit = self._fetch_time(b, worker, clock)
            fetch = max(fetch, t)          # parallel fetch: slowest peer wins
            self.metrics.record_access(hit=hit, effective=hit and all_cached,
                                       cause=cause)
            self._policies[self.home[b]].on_access(b)
            pol = self._policies[self.home[b]]
            if isinstance(pol, Belady):
                pol.advance(b)
        dur += fetch
        compute_bytes = sum(self.dag.blocks[b].size for b in task.inputs)
        dur += compute_bytes / hw.compute_bw
        # writing the output: cached outputs are lazily spilled (no cost
        # here); uncached outputs are written through to disk
        if task.output in self._outputs_not_cached or not self.cache_outputs:
            dur += self._disk_io(worker, self.dag.blocks[task.output].size,
                                 clock + dur)
        return dur

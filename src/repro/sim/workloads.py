"""Workload generators mirroring the paper's experiments (§II-C, §IV).

* ``zip_job``: two files, each partitioned into ``n_blocks`` blocks; the
  zip stage pairs block k of file A with block k of file B (paper Fig. 2).
* ``multi_tenant_zip``: 10 tenants × zip jobs over distinct files — the
  §IV EC2 experiment (2 × 400 MB per job, 100 blocks per file).
* ``load_then_zip`` builds the two-stage DAG: a *load* stage materializes
  each source partition from stable storage (populating the cache), then
  the zip stage consumes the pairs.
Arrival-process generators (PR 6) live here too: timed request arrivals
for the serve front door — Poisson (the open-loop baseline), bursty
(on/off, Markov-modulated) and diurnal (sinusoidal rate, thinned) — all
seeded and deterministic, consumed by ``serve.play_trace`` and
``benchmarks/serve_latency.py``.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from ..core import BlockMeta, JobDAG, TaskSpec


def zip_job(job_id: str, n_blocks: int, block_size: int,
            n_workers: int = 20, align_homes: bool = True,
            compute_cost: float = 0.0) -> Tuple[JobDAG, List[str]]:
    """Two-stage job: load A[k], B[k] from stable storage, then zip pairwise.

    Returns (dag, zip_output_ids). Source partitions A*[k]/B*[k] are raw
    external data (not cache-managed); the *load* outputs (the RDD blocks)
    are what the cache manages — exactly Spark's scan-then-persist shape.
    """
    dag = JobDAG()
    zip_outputs: List[str] = []
    for fname in ("A", "B"):
        for k in range(n_blocks):
            home = k % n_workers if align_homes else None
            # raw partition on stable storage
            dag.add_block(BlockMeta(f"{job_id}.{fname}raw[{k}]", block_size,
                                    f"{job_id}.{fname}raw", k, home))
            # materialized (cacheable) RDD block
            dag.add_block(BlockMeta(f"{job_id}.{fname}[{k}]", block_size,
                                    f"{job_id}.{fname}", k, home))
    # load stage: file A first, then file B (paper: files partitioned in
    # order; under LRU the later B-blocks push out the A-blocks)
    for fname in ("A", "B"):
        for k in range(n_blocks):
            dag.add_task(TaskSpec(
                id=f"{job_id}.load{fname}[{k:04d}]",
                inputs=(f"{job_id}.{fname}raw[{k}]",),
                output=f"{job_id}.{fname}[{k}]",
                job=job_id, stage=0))
    # zip stage
    for k in range(n_blocks):
        out = f"{job_id}.Z[{k}]"
        dag.add_block(BlockMeta(out, 2 * block_size, f"{job_id}.Z", k,
                                k % n_workers if align_homes else None))
        dag.add_task(TaskSpec(
            id=f"{job_id}.zip[{k:04d}]",
            inputs=(f"{job_id}.A[{k}]", f"{job_id}.B[{k}]"),
            output=out, job=job_id, stage=1))
        zip_outputs.append(out)
    return dag, zip_outputs


def multi_tenant_zip(n_jobs: int = 10, n_blocks: int = 100,
                     file_mb: int = 400, n_workers: int = 20
                     ) -> List[Tuple[JobDAG, List[str]]]:
    """The paper's §IV workload: 10 tenants, 2 × 400 MB files each,
    100 blocks per file → 8 GB of cacheable input blocks in total."""
    block_size = file_mb * 2 ** 20 // n_blocks
    return [zip_job(f"job{j}", n_blocks, block_size, n_workers)
            for j in range(n_jobs)]


def zip_access_trace(n_jobs: int, n_blocks: int) -> List[str]:
    """Approximate future block-access order for the Belady oracle:
    round-robin over jobs, zip tasks in partition order."""
    trace: List[str] = []
    for k in range(n_blocks):
        for j in range(n_jobs):
            trace.append(f"job{j}.A[{k}]")
            trace.append(f"job{j}.B[{k}]")
    return trace


def coalesce_job(job_id: str, n_groups: int, group_size: int,
                 block_size: int, n_workers: int = 20
                 ) -> Tuple[JobDAG, List[str]]:
    """k-ary peer groups (Spark coalesce/join with ``group_size`` inputs):
    the all-or-nothing property sharpens as k grows — the probability that
    a peer-oblivious policy keeps ALL k inputs resident falls
    geometrically, so LERC's advantage should WIDEN with k (paper §II-C
    names join/coalesce alongside zip; this workload measures the claim)."""
    dag = JobDAG()
    outputs: List[str] = []
    for g in range(n_groups):
        for j in range(group_size):
            home = (g * group_size + j) % n_workers
            dag.add_block(BlockMeta(f"{job_id}.raw[{g}.{j}]", block_size,
                                    f"{job_id}.raw{g}", j, home))
            dag.add_block(BlockMeta(f"{job_id}.in[{g}.{j}]", block_size,
                                    f"{job_id}.in{g}", j, home))
    # load order is FILE-major (input j of every group together), matching
    # Spark scanning k input RDDs one file at a time — the interleaving
    # that defeats recency (the paper's Fig. 1 mechanism, generalized)
    for j in range(group_size):
        for g in range(n_groups):
            dag.add_task(TaskSpec(
                id=f"{job_id}.load[{j:02d}.{g:03d}]",
                inputs=(f"{job_id}.raw[{g}.{j}]",),
                output=f"{job_id}.in[{g}.{j}]", job=job_id, stage=0))
    for g in range(n_groups):
        out = f"{job_id}.C[{g}]"
        dag.add_block(BlockMeta(out, group_size * block_size,
                                f"{job_id}.C", g, g % n_workers))
        dag.add_task(TaskSpec(
            id=f"{job_id}.coalesce[{g:03d}]",
            inputs=tuple(f"{job_id}.in[{g}.{j}]"
                         for j in range(group_size)),
            output=out, job=job_id, stage=1))
        outputs.append(out)
    return dag, outputs


# ---------------------------------------------------------------------------
# Arrival processes (PR 6): timed request arrivals for the serve front door
# ---------------------------------------------------------------------------


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> List[float]:
    """``n`` arrival times of a homogeneous Poisson process with ``rate``
    arrivals per unit of virtual time (exponential i.i.d. gaps)."""
    assert rate > 0
    rng = np.random.default_rng(seed)
    return list(np.cumsum(rng.exponential(1.0 / rate, size=n)))


def bursty_arrivals(n: int, rate: float, seed: int = 0, *,
                    burst_factor: float = 8.0, p_burst: float = 0.15,
                    mean_burst: int = 8) -> List[float]:
    """On/off (Markov-modulated) Poisson arrivals: the process alternates
    between a quiet phase at ``rate`` and bursts of ~``mean_burst``
    requests arriving ``burst_factor``× faster — the flash-crowd shape
    that separates deadline-aware scheduling from FCFS hardest."""
    assert rate > 0
    rng = np.random.default_rng(seed)
    t, out, left = 0.0, [], 0           # left = arrivals left in the burst
    while len(out) < n:
        if left == 0 and rng.random() < p_burst:
            left = 1 + rng.geometric(1.0 / mean_burst)
        r = rate * burst_factor if left > 0 else rate
        left = max(left - 1, 0)
        t += rng.exponential(1.0 / r)
        out.append(t)
    return out


def diurnal_arrivals(n: int, rate: float, seed: int = 0, *,
                     period: float = 200.0, depth: float = 0.8
                     ) -> List[float]:
    """Non-homogeneous Poisson arrivals with a sinusoidal rate
    ``rate * (1 + depth * sin(2πt/period))`` — the day/night load swing —
    generated by thinning against the peak rate."""
    assert rate > 0 and 0 <= depth <= 1
    rng = np.random.default_rng(seed)
    peak = rate * (1 + depth)
    t, out = 0.0, []
    while len(out) < n:
        t += rng.exponential(1.0 / peak)
        lam = rate * (1 + depth * np.sin(2 * np.pi * t / period))
        if rng.random() < lam / peak:
            out.append(t)
    return out

"""Cache performance metrics.

The paper's point (§III-A, Figs. 6–7): the *effective* cache hit ratio —
hits whose whole peer group is resident — predicts job runtime; the plain
hit ratio does not.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass
class CacheMetrics:
    accesses: int = 0
    hits: int = 0
    effective_hits: int = 0
    evictions: int = 0
    disk_bytes_read: int = 0
    mem_bytes_read: int = 0
    # ---- tiered stores (serve.TieredKVStore; core's mem/disk analogue) ----
    # ``hits`` counts presence in ANY tier; ``tier1_hits``/``tier2_hits``
    # are the slices served by the host/disk tiers (hits that pay a
    # promotion copy, not a recompute). Effective hits are tier-0-only by
    # Def. 1: the whole peer group must sit in the fast tier.
    tier1_hits: int = 0
    tier2_hits: int = 0
    demotions: int = 0        # fast tier -> host tier (block survives)
    promotions: int = 0       # slower tier -> fast tier (chain reused)
    host_evictions: int = 0   # out of the host tier, no disk tier to catch
    # ---- the disk rung (PR 8) ----
    disk_demotions: int = 0   # host tier -> disk tier (block survives again)
    disk_promotions: int = 0  # the slice of ``promotions`` sourced from disk
    disk_evictions: int = 0   # out of the disk tier (block finally dies)
    # ---- transcoding + dispatch economics ----
    quantized_demotions: int = 0     # demotions that narrowed the dtype
    dequantized_promotions: int = 0  # promotions that widened it back
    promotion_dispatches: int = 0    # batched transfers (1 per tier per
    #                                  promotion, however many blocks ride)

    def record_access(self, hit: bool, effective: bool,
                      tier: int = 0) -> None:
        self.accesses += 1
        if hit:
            self.hits += 1
            if tier == 1:
                self.tier1_hits += 1
            elif tier == 2:
                self.tier2_hits += 1
        if effective:
            if not hit:
                raise ValueError("an effective hit must be a hit")
            if tier != 0:
                raise ValueError("an effective hit must be a fast-tier hit")
            self.effective_hits += 1

    @property
    def hit_ratio(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0

    @property
    def effective_hit_ratio(self) -> float:
        return self.effective_hits / self.accesses if self.accesses else 0.0

    def merge(self, other: "CacheMetrics") -> "CacheMetrics":
        return CacheMetrics(
            accesses=self.accesses + other.accesses,
            hits=self.hits + other.hits,
            effective_hits=self.effective_hits + other.effective_hits,
            evictions=self.evictions + other.evictions,
            disk_bytes_read=self.disk_bytes_read + other.disk_bytes_read,
            mem_bytes_read=self.mem_bytes_read + other.mem_bytes_read,
            tier1_hits=self.tier1_hits + other.tier1_hits,
            tier2_hits=self.tier2_hits + other.tier2_hits,
            demotions=self.demotions + other.demotions,
            promotions=self.promotions + other.promotions,
            host_evictions=self.host_evictions + other.host_evictions,
            disk_demotions=self.disk_demotions + other.disk_demotions,
            disk_promotions=self.disk_promotions + other.disk_promotions,
            disk_evictions=self.disk_evictions + other.disk_evictions,
            quantized_demotions=(self.quantized_demotions
                                 + other.quantized_demotions),
            dequantized_promotions=(self.dequantized_promotions
                                    + other.dequantized_promotions),
            promotion_dispatches=(self.promotion_dispatches
                                  + other.promotion_dispatches),
        )

    def as_dict(self) -> Dict[str, float]:
        return {
            "accesses": self.accesses,
            "hits": self.hits,
            "effective_hits": self.effective_hits,
            "evictions": self.evictions,
            "hit_ratio": self.hit_ratio,
            "effective_hit_ratio": self.effective_hit_ratio,
            "disk_bytes_read": self.disk_bytes_read,
            "mem_bytes_read": self.mem_bytes_read,
            "tier1_hits": self.tier1_hits,
            "tier2_hits": self.tier2_hits,
            "demotions": self.demotions,
            "promotions": self.promotions,
            "host_evictions": self.host_evictions,
            "disk_demotions": self.disk_demotions,
            "disk_promotions": self.disk_promotions,
            "disk_evictions": self.disk_evictions,
            "quantized_demotions": self.quantized_demotions,
            "dequantized_promotions": self.dequantized_promotions,
            "promotion_dispatches": self.promotion_dispatches,
        }


@dataclass
class MessageStats:
    """Coordination-protocol traffic (paper §III-C).

    Counts are split into the LERC-specific channel (peer-profile
    broadcasts + eviction reports/broadcasts — the paper's overhead claim)
    and the legacy block-status channel that exists regardless of LERC
    (Spark's BlockManagerMaster updates). ``point_to_point`` counts every
    individual message on the wire across both channels; the byte counters
    measure serialized payload sizes so overhead is reportable in bytes as
    well as message counts.
    """

    peer_profile_broadcasts: int = 0      # job submit: peer info -> workers
    eviction_reports: int = 0             # worker -> master
    eviction_broadcasts: int = 0          # master -> all workers
    point_to_point: int = 0               # individual messages on the wire
    payload_bytes: int = 0                # serialized payload bytes, all msgs
    lerc_bytes: int = 0                   # ...restricted to the LERC channel

    def as_dict(self) -> Dict[str, int]:
        return {
            "peer_profile_broadcasts": self.peer_profile_broadcasts,
            "eviction_reports": self.eviction_reports,
            "eviction_broadcasts": self.eviction_broadcasts,
            "point_to_point": self.point_to_point,
            "payload_bytes": self.payload_bytes,
            "lerc_bytes": self.lerc_bytes,
        }

"""Cache performance metrics.

The paper's point (§III-A, Figs. 6–7): the *effective* cache hit ratio —
hits whose whole peer group is resident — predicts job runtime; the plain
hit ratio does not.

``merge``/``as_dict`` are derived from ``dataclasses.fields`` so a
counter added by a future PR is aggregated and reported automatically —
the hand-maintained three-place copies these replaced silently dropped
any field someone forgot (``tests/test_obs.py`` round-trips every field
through both).

Effective-hit **attribution** (the obs PR): every ineffective hit
increments exactly one bucket of ``ineffective_by_cause`` — where the
first blocking peer block of its group/chain was sitting at access time:

* ``"host"`` / ``"disk"`` — demoted to a slower tier (a promotion copy,
  not a recompute, would complete the group);
* ``"evicted"`` — was resident once and died (the policy's fault);
* ``"never_cached"`` — never entered the cache at all (cold chain);
* ``"unattributed"`` — the caller recorded no cause.

Conservation holds structurally: ``sum(ineffective_by_cause.values())
== hits - effective_hits`` after any interleaving of ``record_access``
and ``merge`` (``check_attribution`` asserts it; the stores and the sim
call it on every metrics read).
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Dict, Optional


def _merged(a, b):
    """Field-derived dataclass merge: numeric fields sum, dict-valued
    counter fields sum key-wise."""
    kw = {}
    for f in fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, dict):
            out = dict(va)
            for k, v in vb.items():
                out[k] = out.get(k, 0) + v
            kw[f.name] = out
        else:
            kw[f.name] = va + vb
    return type(a)(**kw)


def _field_dict(obj) -> Dict[str, object]:
    """Every dataclass field, in declaration order; dict-valued fields
    are copied so callers can't mutate the live counters."""
    return {f.name: (dict(v) if isinstance(v, dict) else v)
            for f in fields(obj)
            for v in (getattr(obj, f.name),)}


@dataclass
class CacheMetrics:
    accesses: int = 0
    hits: int = 0
    effective_hits: int = 0
    evictions: int = 0
    disk_bytes_read: int = 0
    mem_bytes_read: int = 0
    # ---- tiered stores (serve.TieredKVStore; core's mem/disk analogue) ----
    # ``hits`` counts presence in ANY tier; ``tier1_hits``/``tier2_hits``
    # are the slices served by the host/disk tiers (hits that pay a
    # promotion copy, not a recompute). Effective hits are tier-0-only by
    # Def. 1: the whole peer group must sit in the fast tier.
    tier1_hits: int = 0
    tier2_hits: int = 0
    demotions: int = 0        # fast tier -> host tier (block survives)
    promotions: int = 0       # slower tier -> fast tier (chain reused)
    host_evictions: int = 0   # out of the host tier, no disk tier to catch
    # ---- the disk rung (PR 8) ----
    disk_demotions: int = 0   # host tier -> disk tier (block survives again)
    disk_promotions: int = 0  # the slice of ``promotions`` sourced from disk
    disk_evictions: int = 0   # out of the disk tier (block finally dies)
    # ---- transcoding + dispatch economics ----
    quantized_demotions: int = 0     # demotions that narrowed the dtype
    dequantized_promotions: int = 0  # promotions that widened it back
    promotion_dispatches: int = 0    # batched transfers (1 per tier per
    #                                  promotion, however many blocks ride)
    # ---- fault injection + graceful degradation (robustness PR) ----
    disk_io_errors: int = 0          # injected/real OSErrors on the disk tier
    disk_quarantines: int = 0        # disk tiers taken out of rotation
    promotion_stalls: int = 0        # slow promotions charged to the clock
    promotion_timeouts: int = 0      # promotions abandoned past the budget
    # ---- effective-hit attribution (obs PR): ineffective hits bucketed
    # by where the first blocking peer block sat at access time ----
    ineffective_by_cause: Dict[str, int] = field(default_factory=dict)

    def record_access(self, hit: bool, effective: bool, tier: int = 0,
                      cause: Optional[str] = None) -> None:
        self.accesses += 1
        if hit:
            self.hits += 1
            if tier == 1:
                self.tier1_hits += 1
            elif tier == 2:
                self.tier2_hits += 1
        if effective:
            if not hit:
                raise ValueError("an effective hit must be a hit")
            if tier != 0:
                raise ValueError("an effective hit must be a fast-tier hit")
            self.effective_hits += 1
        elif hit:
            # every ineffective hit lands in exactly one bucket, so the
            # conservation invariant cannot drift no matter the caller
            c = cause or "unattributed"
            self.ineffective_by_cause[c] = \
                self.ineffective_by_cause.get(c, 0) + 1

    @property
    def hit_ratio(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0

    @property
    def effective_hit_ratio(self) -> float:
        return self.effective_hits / self.accesses if self.accesses else 0.0

    def check_attribution(self) -> None:
        got = sum(self.ineffective_by_cause.values())
        want = self.hits - self.effective_hits
        if got != want:
            raise AssertionError(
                f"ineffective-hit attribution leaked: "
                f"sum(causes)={got} != hits-effective={want} "
                f"({self.ineffective_by_cause})")

    def merge(self, other: "CacheMetrics") -> "CacheMetrics":
        return _merged(self, other)

    def as_dict(self) -> Dict[str, float]:
        return {**_field_dict(self),
                "hit_ratio": self.hit_ratio,
                "effective_hit_ratio": self.effective_hit_ratio}


@dataclass
class MessageStats:
    """Coordination-protocol traffic (paper §III-C).

    Counts are split into the LERC-specific channel (peer-profile
    broadcasts + eviction reports/broadcasts — the paper's overhead claim)
    and the legacy block-status channel that exists regardless of LERC
    (Spark's BlockManagerMaster updates). ``point_to_point`` counts every
    individual message on the wire across both channels; the byte counters
    measure serialized payload sizes so overhead is reportable in bytes as
    well as message counts (zeros on a bus running at stats level
    ``"counts"``, which skips payload sizing entirely).
    """

    peer_profile_broadcasts: int = 0      # job submit: peer info -> workers
    eviction_reports: int = 0             # worker -> master
    eviction_broadcasts: int = 0          # master -> all workers
    point_to_point: int = 0               # individual messages on the wire
    payload_bytes: int = 0                # serialized payload bytes, all msgs
    lerc_bytes: int = 0                   # ...restricted to the LERC channel
    # ---- fault injection + recovery (robustness PR) ----
    dropped: int = 0                      # messages lost to injected faults
    delayed: int = 0                      # ... delivered late
    duplicated: int = 0                   # ... delivered twice
    resyncs: int = 0                      # anti-entropy snapshots served
    diverged_applies: int = 0             # status folds skipped on replicas
    #                                       already diverged by lost traffic

    def merge(self, other: "MessageStats") -> "MessageStats":
        return _merged(self, other)

    def as_dict(self) -> Dict[str, int]:
        return _field_dict(self)

"""Distributed coordination of effective reference counts (paper §III-C).

This module is the system's ONE coordination plane: both the cluster
simulator (``sim.ClusterSim``, one ``PeerTracker`` + ``CacheManager`` per
worker) and the sharded serving tier (``serve.ShardedFrontend``, one
``PeerTracker`` per cache shard) run their cross-worker state through it.

Architecture mirrors the paper's Spark implementation:

* ``PeerTrackerMaster`` (driver): holds the authoritative composed
  ``JobDAG``/``DagState``, broadcasts the *peer-information profile* —
  incrementally, only each job's new blocks and tasks — and relays both
  channels below.
* ``PeerTracker`` (one per worker/shard): owns a full ``JobDAG`` +
  ``DagState`` replica updated *only* through bus messages (plus the local
  events of its co-located cache manager), so tests can diff it against a
  centrally-fed oracle.

Two message channels, accounted separately:

* **LERC channel** (the paper's overhead claim): ``peer_profile``
  broadcasts at job submission, and ``evict_report`` (worker → master) +
  ``evict_bcast`` (master → workers) when a *local* eviction breaks at
  least one **complete** peer group. Evictions of blocks whose groups are
  all already incomplete are silent on this channel. Counted in
  ``MessageStats.{peer_profile_broadcasts,eviction_reports,
  eviction_broadcasts,lerc_bytes}``.
* **Legacy status channel** (exists regardless of LERC — Spark's
  ``BlockManagerMaster`` block-status updates): every local block/task
  event is reported worker → master (``status_report``), folded into the
  master's authoritative state, and relayed to all workers (``status``) so
  replicas stay coherent even across silent evictions. Counted only in
  ``point_to_point``/``payload_bytes``, so the LERC-specific overhead is
  measurable on its own.

The paper's communication-overhead claim, implemented and property-tested
here: **between two completeness transitions of a peer group, at most one
eviction broadcast is triggered for that group** — once a group flips to
incomplete, further evictions of its members cost no LERC messages (until
a reload makes it complete again).
"""
from __future__ import annotations

import heapq
import itertools
import pickle
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..obs.trace import TID_BUS as _TID_BUS
from .dag import BlockId, DagState, JobDAG, TaskId
from .metrics import MessageStats

# message kinds that belong to the LERC-specific channel (vs legacy status)
LERC_KINDS = frozenset({"peer_profile", "evict_report", "evict_bcast"})

# anti-entropy kinds ride a reliable RPC channel (they ARE the recovery
# mechanism): fault injection never drops, delays or duplicates them
RESYNC_KINDS = frozenset({"resync_request", "resync"})


def payload_nbytes(payload: tuple) -> int:
    """Serialized wire size of a message payload. The in-process bus never
    actually serializes; pickle gives an honest, deterministic estimate of
    what an RPC transport would put on the wire."""
    return len(pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL))


def _shape_key(payload) -> Optional[tuple]:
    """Cache key under which two payloads are guaranteed to pickle to the
    SAME number of bytes — or None when we can't guarantee it (then the
    caller pickles for real). Covers the hot wire shapes: flat tuples of
    ≤4 primitives, which is every status/eviction message on the bus.

    The guarantees lean on pickle's fixed-width opcodes at
    ``HIGHEST_PROTOCOL``: a str costs opcode + length prefix + its UTF-8
    bytes (prefix width switches on the *byte* length, so key on that);
    an int costs a fixed frame by magnitude class (BININT1 < 256,
    BININT2 < 65536, BININT within int32 — wider ints bail); bool/None
    are single opcodes keyed by value; a float is a fixed 9-byte
    BINFLOAT. One trap: pickle memoizes by object *identity*, so a
    repeated string object would shrink to a back-reference — bail on
    identity-duplicate strings."""
    if type(payload) is not tuple or len(payload) > 4:
        return None
    key: list = [len(payload)]
    str_ids: set = set()
    for v in payload:
        t = type(v)
        if t is str:
            if id(v) in str_ids:
                return None
            str_ids.add(id(v))
            key.append(("s", len(v.encode("utf-8"))))
        elif t is bool or v is None:
            key.append(("c", v))
        elif t is int:
            if 0 <= v < 256:
                key.append(("i", 1))
            elif 0 <= v < 65536:
                key.append(("i", 2))
            elif -2 ** 31 <= v < 2 ** 31:
                key.append(("i", 4))
            else:
                return None
        elif t is float:
            key.append(("f",))
        else:
            return None
    return tuple(key)


@dataclass
class Message:
    kind: str        # "peer_profile" | "status_report" | "status"
    #                  | "evict_report" | "evict_bcast"
    payload: tuple
    src: str
    dst: str
    nbytes: Optional[int] = None   # filled by the bus (or precomputed once
    #                                per broadcast) at send time


class MessageBus:
    """Synchronous in-process bus with per-message accounting. A real
    deployment would replace this with RPC endpoints; the protocol logic
    above it is identical. ``record_log`` keeps the full message log for
    tests; long-running embedders (the simulator, the serve frontend) turn
    it off so memory stays bounded.

    ``stats_level`` gates how much accounting each send pays:

    * ``"full"`` (default) — message counts AND serialized payload bytes.
      Sizing pickles the payload, but repeated wire shapes (flat tuples of
      ≤4 primitives — every status/eviction message) hit a shape-keyed
      size cache, so the steady state is a dict lookup, not a pickle. The
      cache is exact: ``tests/test_obs.py`` asserts byte counters are
      unchanged vs. sizing every payload from scratch.
    * ``"counts"`` — skip payload sizing entirely; the byte counters stay
      zero, the count counters are identical to ``"full"``.
    """

    def __init__(self, record_log: bool = True,
                 stats_level: str = "full") -> None:
        if stats_level not in ("full", "counts"):
            raise ValueError(f"stats_level must be full|counts, "
                             f"got {stats_level!r}")
        self.stats = MessageStats()
        self.record_log = record_log
        self.stats_level = stats_level
        self.log: List[Message] = []
        self._endpoints: Dict[str, Callable[[Message], None]] = {}
        self._size_cache: Dict[tuple, int] = {}
        # obs: an attached ``repro.obs.TraceRecorder`` (None = off)
        self.trace = None
        self.trace_pid = 0
        # fault injection (repro.faults.FaultInjector, None = healthy bus).
        # ``now`` is the embedder's virtual clock, advanced via
        # ``flush_delayed``; delayed messages deliver when it passes their
        # due time, in (due, send-order) order — i.e. possibly reordered
        # relative to healthy traffic.
        self.faults = None
        self.now = 0.0
        self._delayed: List[Tuple[float, int, Message]] = []
        self._dseq = itertools.count()

    def register(self, name: str, handler: Callable[[Message], None]) -> None:
        self._endpoints[name] = handler

    def payload_nbytes(self, payload: tuple) -> int:
        """Wire size of ``payload`` under this bus's stats level: 0 at
        ``"counts"``; at ``"full"`` the exact pickled size, via the shape
        cache when the payload's shape guarantees a fixed size."""
        if self.stats_level == "counts":
            return 0
        key = _shape_key(payload)
        if key is None:
            return payload_nbytes(payload)
        n = self._size_cache.get(key)
        if n is None:
            n = payload_nbytes(payload)
            self._size_cache[key] = n
        return n

    def send(self, msg: Message) -> None:
        if msg.nbytes is None:
            msg.nbytes = self.payload_nbytes(msg.payload)
        if self.record_log:
            self.log.append(msg)
        self.stats.point_to_point += 1
        self.stats.payload_bytes += msg.nbytes
        if msg.kind in LERC_KINDS:
            self.stats.lerc_bytes += msg.nbytes
        if self.trace is not None:
            self.trace.instant(
                "bus." + msg.kind, "bus", self.trace_pid, _TID_BUS,
                args={"src": msg.src, "dst": msg.dst, "bytes": msg.nbytes})
        if self.faults is not None and msg.kind not in RESYNC_KINDS:
            act = self.faults.bus_action(msg.kind)
            if act is not None:
                if act[0] == "drop":
                    self.stats.dropped += 1
                    self.faults.count("fault.bus_drop")
                    if self.trace is not None:
                        self.trace.instant(
                            "fault.bus_drop", "bus", self.trace_pid,
                            _TID_BUS, args={"kind": msg.kind,
                                            "dst": msg.dst})
                    return
                if act[0] == "delay":
                    self.stats.delayed += 1
                    self.faults.count("fault.bus_delay")
                    heapq.heappush(self._delayed,
                                   (self.now + act[1], next(self._dseq),
                                    msg))
                    return
                # duplicate: the message arrives twice (handlers are
                # idempotent by protocol design — this proves it)
                self.stats.duplicated += 1
                self.faults.count("fault.bus_dup")
                self._endpoints[msg.dst](msg)
        self._endpoints[msg.dst](msg)

    def flush_delayed(self, now: float) -> int:
        """Advance the bus clock and deliver every delayed message now due,
        in (due time, send order). Returns the number delivered."""
        self.now = max(self.now, now)
        n = 0
        while self._delayed and self._delayed[0][0] <= self.now:
            _, _, msg = heapq.heappop(self._delayed)
            self._endpoints[msg.dst](msg)
            n += 1
        return n


def apply_status(state: DagState, event: str, ident,
                 eviction_log: Optional[List[BlockId]] = None) -> None:
    """Fold one legacy-channel status event into ``state``. Handlers are
    idempotent, so the worker that originated an event (and already applied
    it locally) can safely receive the relayed broadcast."""
    if event == "materialized":
        state.on_materialized(ident, into_cache=True)
    elif event == "materialized_disk":
        state.on_materialized(ident, into_cache=False)
    elif event == "loaded":
        state.on_loaded(ident)
    elif event == "evicted":
        if eviction_log is not None and ident in state.cached:
            eviction_log.append(ident)
        state.on_evicted(ident)
    elif event == "lost":
        # crash loss: no disk copy survives, so the producer must re-run
        # (lineage recompute). Relayed like a silent eviction so every
        # replica resurrects the same references.
        if eviction_log is not None and ident in state.cached:
            eviction_log.append(ident)
        state.on_lost(ident)
    elif event == "task_done":
        state.on_task_done(ident)
    elif event == "task_removed":
        # serve: a request chain's references left the system; mirror the
        # store's retirement (settle counters, drop the task + its virtual
        # output) so replicas track the live working set, not history
        if ident in state.dag.tasks:
            state.on_task_removed(ident)
            state.dag.remove_task(ident, remove_output=True)
    elif event == "forget_block":
        # serve: radix-skeleton GC of an unreferenced, non-resident node.
        # DAG-less replicas (the policy ships no peer profile) still drop
        # the block from their residency sets so those stay bounded.
        state.forget_block(ident)
        # tolerate replicas that are mid-divergence (dropped/duplicated
        # status traffic, crash purges): only detach the skeleton node if
        # it is genuinely unreferenced here too
        if (ident in state.dag.blocks
                and not state.dag.consumers.get(ident)
                and ident not in state.dag.producer):
            state.dag.remove_block(ident)
    else:
        raise ValueError(f"unknown status event {event!r}")


class PeerTracker:
    """Worker-side tracker: a full replica of the composed DAG, the
    peer-group completeness labels and the ERC counts.

    The replica (``self.dag`` + ``self.state``) exists from construction,
    so a co-located ``CacheManager``/``EvictionIndex`` can be built over it
    before any job arrives; peer profiles then extend it incrementally
    (``add_block``/``add_task`` + ``on_task_added`` — no rebuilds).
    """

    def __init__(self, worker_id: int, bus: MessageBus) -> None:
        self.worker_id = worker_id
        self.name = f"worker:{worker_id}"
        self.bus = bus
        self.dag = JobDAG()
        self.state = DagState(self.dag)
        # evictions applied to this replica *via bus messages*, in order
        # (local evictions applied directly to a shared state by the
        # co-located manager are deduplicated by residency). Follows the
        # bus's record_log flag: long-running embedders that bound the
        # message log also bound this, test clusters keep both.
        self.record_eviction_log = bus.record_log
        self.eviction_log: List[BlockId] = []
        bus.register(self.name, self.handle)

    # --------------------------------------------------------------- handler
    def handle(self, msg: Message) -> None:
        if msg.kind == "peer_profile":
            blocks, tasks = msg.payload
            for b in blocks:
                if b.id not in self.dag.blocks:
                    self.dag.add_block(b)
            for t in tasks:
                if t.id not in self.dag.tasks:
                    self.dag.add_task(t)
                    self.state.on_task_added(t.id)
        elif msg.kind == "status":
            event, ident = msg.payload
            try:
                apply_status(self.state, event, ident,
                             eviction_log=(self.eviction_log
                                           if self.record_eviction_log
                                           else None))
            except KeyError:
                if self.bus.faults is None:
                    raise
                # a lossy bus already skipped earlier updates, so later
                # ones can hit state they assume present; the replica is
                # diverged either way and anti-entropy resync is the
                # repair path — folding must not kill the worker
                self.bus.stats.diverged_applies += 1
        elif msg.kind == "evict_bcast":
            (block,) = msg.payload
            if self.record_eviction_log and block in self.state.cached:
                self.eviction_log.append(block)
            try:
                self.state.on_evicted(block)
            except KeyError:
                if self.bus.faults is None:
                    raise
                self.bus.stats.diverged_applies += 1
        elif msg.kind == "resync":
            self._install_snapshot(msg.payload)

    # ------------------------------------------------------------ anti-entropy
    def request_resync(self, include_dag: bool = True) -> None:
        """Ask the master for an authoritative snapshot (anti-entropy):
        used to seed a freshly rebuilt replica after a crash, or to
        reconverge one that drifted behind dropped status traffic.
        ``include_dag=False`` skips DAG structure (replicas on a cluster
        that ships no peer profiles deliberately stay DAG-less)."""
        self.bus.send(Message("resync_request",
                              (self.worker_id, include_dag),
                              src=self.name, dst="master"))

    def _install_snapshot(self, snap: tuple) -> None:
        """Replace this replica's view with the master's. The DagState
        object is mutated IN PLACE (co-located cache managers and eviction
        indexes hold references to it), then ``rebuild()`` re-derives every
        counter so listeners resort their keys."""
        blocks, tasks, materialized, cached, done = snap
        if blocks is not None:
            want_b = {b.id for b in blocks}
            want_t = {t.id for t in tasks}
            for tid in [t for t in self.dag.tasks if t not in want_t]:
                self.dag.remove_task(tid)
            for bid in [b for b in self.dag.blocks if b not in want_b]:
                if (not self.dag.consumers.get(bid)
                        and bid not in self.dag.producer):
                    self.dag.remove_block(bid)
            for b in blocks:
                if b.id not in self.dag.blocks:
                    self.dag.add_block(b)
            for t in tasks:
                if t.id not in self.dag.tasks:
                    self.dag.add_task(t)
        self.state.materialized = set(materialized)
        self.state.cached = set(cached)
        self.state.done_tasks = set(done)
        self.state.rebuild()

    # ----------------------------------------------------------- local event
    def local_eviction(self, block: BlockId) -> bool:
        """A local eviction not yet applied to the replica: apply it, then
        run the full protocol — the paper's reporting rule on the LERC
        channel plus the legacy status update (so the master and every
        peer replica learn of silent evictions too). Returns True iff a
        report (and hence a broadcast) was triggered."""
        if self.record_eviction_log and block in self.state.cached:
            self.eviction_log.append(block)
        flipped = self.state.on_evicted(block)
        reported = self.report_eviction(block, flipped)
        self.report_status("evicted", block)
        return reported

    def report_eviction(self, block: BlockId,
                        flipped_groups: Sequence[TaskId]) -> bool:
        """Paper §III-C worker-side rule, for callers whose cache manager
        already applied the eviction to the local state: report to the
        master iff the eviction broke at least one complete peer group
        (``flipped_groups`` is ``DagState.on_evicted``'s return value).
        Evictions out of already-incomplete groups are silent."""
        if not flipped_groups:
            return False
        self.bus.stats.eviction_reports += 1
        self.bus.send(Message("evict_report", (block,),
                              src=self.name, dst="master"))
        return True

    def report_status(self, event: str, ident) -> None:
        """Legacy BlockManagerMaster channel: one point-to-point message to
        the master, which folds it into the authoritative state and relays
        it to every worker."""
        self.bus.send(Message("status_report", (event, ident),
                              src=self.name, dst="master"))


class PeerTrackerMaster:
    """Driver-side: authoritative composed DAG + state, peer-profile
    broadcasts, eviction-report relay, and the legacy status relay."""

    def __init__(self, bus: MessageBus, n_workers: int) -> None:
        self.bus = bus
        self.n_workers = n_workers
        self.dag = JobDAG()
        self.state = DagState(self.dag)
        bus.register("master", self.handle)

    # ------------------------------------------------------------ job submit
    def submit_job(self, job_dag: JobDAG, broadcast: bool = True
                   ) -> Tuple[List, List]:
        """Merge the job's DAG into the composed multi-job DAG — applied
        incrementally to the authoritative state — and broadcast the *new*
        blocks and tasks as the peer-information profile (paper: via
        BlockManagerMasterEndpoint). ``broadcast=False`` skips the LERC
        profile (a cluster running a DAG-oblivious policy ships no peer
        information). Returns (new_blocks, new_tasks)."""
        new_blocks = [b for b in job_dag.blocks.values()
                      if b.id not in self.dag.blocks]
        new_tasks = [t for t in job_dag.tasks.values()
                     if t.id not in self.dag.tasks]
        for b in new_blocks:
            self.dag.add_block(b)
        for t in new_tasks:
            self.dag.add_task(t)
            self.state.on_task_added(t.id)
        if broadcast:
            self.bus.stats.peer_profile_broadcasts += 1
            self._broadcast("peer_profile",
                            (tuple(new_blocks), tuple(new_tasks)))
        return new_blocks, new_tasks

    # ----------------------------------------------------------------- relay
    def handle(self, msg: Message) -> None:
        if msg.kind == "evict_report":
            (block,) = msg.payload
            self.bus.stats.eviction_broadcasts += 1
            self._broadcast("evict_bcast", (block,))
        elif msg.kind == "status_report":
            event, ident = msg.payload
            apply_status(self.state, event, ident)
            self._broadcast("status", (event, ident))
        elif msg.kind == "resync_request":
            worker, include_dag = msg.payload
            self.bus.stats.resyncs += 1
            self.bus.send(Message("resync", self._snapshot(include_dag),
                                  src="master", dst=f"worker:{worker}"))

    def _snapshot(self, include_dag: bool = True) -> tuple:
        """Authoritative state snapshot for the anti-entropy ``resync``
        reply: (blocks, tasks, materialized, cached, done_tasks) — the
        first two None when the requester keeps a DAG-less replica."""
        dag = (tuple(self.dag.blocks.values()) if include_dag else None,
               tuple(self.dag.tasks.values()) if include_dag else None)
        return (*dag,
                tuple(sorted(self.state.materialized)),
                tuple(sorted(self.state.cached)),
                tuple(sorted(self.state.done_tasks)))

    def status_update(self, event: str, block_or_task) -> None:
        """Driver-originated status (legacy channel): fold into the
        authoritative state and broadcast to all workers."""
        apply_status(self.state, event, block_or_task)
        self._broadcast("status", (event, block_or_task))

    def _broadcast(self, kind: str, payload: tuple) -> None:
        nbytes = self.bus.payload_nbytes(payload)
        for w in range(self.n_workers):
            self.bus.send(Message(kind, payload, src="master",
                                  dst=f"worker:{w}", nbytes=nbytes))


def build_cluster(n_workers: int, record_log: bool = True,
                  stats_level: str = "full"
                  ) -> Tuple[PeerTrackerMaster, List[PeerTracker], MessageBus]:
    bus = MessageBus(record_log=record_log, stats_level=stats_level)
    workers = [PeerTracker(w, bus) for w in range(n_workers)]
    master = PeerTrackerMaster(bus, n_workers)
    return master, workers, bus

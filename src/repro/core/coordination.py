"""Distributed coordination of effective reference counts (paper §III-C).

Architecture mirrors the paper's Spark implementation:

* ``PeerTrackerMaster`` (driver): parses peer groups out of each submitted
  job DAG and broadcasts the *peer-information profile* once per job.
* ``PeerTracker`` (one per worker): holds a replica of the peer-group
  completeness labels and the effective reference counts. On a *local*
  eviction of a block that belongs to at least one **complete** peer group,
  it reports to the master, which broadcasts the eviction to all workers.
  Evictions of blocks in already-incomplete groups are silent.

The paper's communication-overhead claim, implemented and property-tested
here: **between two completeness transitions of a peer group, at most one
eviction broadcast is triggered for that group** — once a group flips to
incomplete, further evictions of its members cost no messages (until a
reload makes it complete again).

Block *materialization / load* status flows over the legacy Spark
``BlockManagerMaster`` channel (it exists regardless of LERC); we count it
separately in ``MessageStats.point_to_point`` so the LERC-specific
overhead (eviction reports + broadcasts) is measurable on its own.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .dag import BlockId, DagState, JobDAG, TaskId
from .metrics import MessageStats


@dataclass
class Message:
    kind: str            # "peer_profile" | "evict_report" | "evict_bcast" | "status"
    payload: tuple
    src: str
    dst: str


class MessageBus:
    """Synchronous in-process bus with per-message accounting. A real
    deployment would replace this with RPC endpoints; the protocol logic
    above it is identical."""

    def __init__(self) -> None:
        self.stats = MessageStats()
        self.log: List[Message] = []
        self._endpoints: Dict[str, Callable[[Message], None]] = {}

    def register(self, name: str, handler: Callable[[Message], None]) -> None:
        self._endpoints[name] = handler

    def send(self, msg: Message) -> None:
        self.log.append(msg)
        self.stats.point_to_point += 1
        self._endpoints[msg.dst](msg)


class PeerTracker:
    """Worker-side tracker: replica of completeness labels + ERC counts.

    The replica maintains a full ``DagState`` updated *only* through bus
    messages, so tests can diff it against a centrally-fed oracle.
    """

    def __init__(self, worker_id: int, bus: MessageBus) -> None:
        self.worker_id = worker_id
        self.name = f"worker:{worker_id}"
        self.bus = bus
        self.state: Optional[DagState] = None
        bus.register(self.name, self.handle)

    # --------------------------------------------------------------- handler
    def handle(self, msg: Message) -> None:
        if msg.kind == "peer_profile":
            (dag,) = msg.payload
            if self.state is None:
                self.state = DagState(dag)
            else:
                # incremental job arrival: rebuild over the composed DAG
                self.state = DagState(
                    dag,
                    materialized=set(self.state.materialized),
                    cached=set(self.state.cached),
                    done_tasks=set(self.state.done_tasks),
                )
        elif msg.kind == "status":
            event, block = msg.payload
            if event == "materialized":
                self.state.on_materialized(block, into_cache=True)
            elif event == "materialized_disk":
                self.state.on_materialized(block, into_cache=False)
            elif event == "loaded":
                self.state.on_loaded(block)
            elif event == "task_done":
                self.state.on_task_done(block)
        elif msg.kind == "evict_bcast":
            (block,) = msg.payload
            self.state.on_evicted(block)

    # ----------------------------------------------------------- local event
    def local_eviction(self, block: BlockId) -> bool:
        """Called by this worker's cache manager when it evicts ``block``.

        Returns True iff a report (and hence a broadcast) was triggered —
        i.e. the block belonged to at least one complete peer group.
        """
        st = self.state
        in_complete_group = any(
            st.task_live(t) and st.group_complete(t)
            for t in st.dag.consumers.get(block, []))
        if not in_complete_group:
            # silent: every group containing it is already incomplete
            st.on_evicted(block)
            return False
        self.bus.stats.eviction_reports += 1
        self.bus.send(Message("evict_report", (block, self.worker_id),
                              src=self.name, dst="master"))
        return True


class PeerTrackerMaster:
    """Driver-side: broadcasts peer profiles and relays eviction reports."""

    def __init__(self, bus: MessageBus, n_workers: int) -> None:
        self.bus = bus
        self.n_workers = n_workers
        self.dag = JobDAG()
        bus.register("master", self.handle)

    # ------------------------------------------------------------ job submit
    def submit_job(self, job_dag: JobDAG) -> None:
        """Merge the job's DAG into the composed multi-job DAG and broadcast
        the peer profile (paper: via BlockManagerMasterEndpoint)."""
        for b in job_dag.blocks.values():
            if b.id not in self.dag.blocks:
                self.dag.add_block(b)
        for t in job_dag.tasks.values():
            if t.id not in self.dag.tasks:
                self.dag.add_task(t)
        self.bus.stats.peer_profile_broadcasts += 1
        self._broadcast("peer_profile", (self.dag,))

    # ----------------------------------------------------------------- relay
    def handle(self, msg: Message) -> None:
        if msg.kind == "evict_report":
            block, _src_worker = msg.payload
            self.bus.stats.eviction_broadcasts += 1
            self._broadcast("evict_bcast", (block,))

    def status_update(self, event: str, block_or_task) -> None:
        """Legacy BlockManagerMaster status channel (not LERC overhead)."""
        self._broadcast("status", (event, block_or_task))

    def _broadcast(self, kind: str, payload: tuple) -> None:
        for w in range(self.n_workers):
            self.bus.send(Message(kind, payload, src="master", dst=f"worker:{w}"))


def build_cluster(n_workers: int) -> Tuple[PeerTrackerMaster, List[PeerTracker], MessageBus]:
    bus = MessageBus()
    workers = [PeerTracker(w, bus) for w in range(n_workers)]
    master = PeerTrackerMaster(bus, n_workers)
    return master, workers, bus

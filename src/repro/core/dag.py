"""Lineage DAG for data-parallel jobs.

This is the paper's substrate: jobs are DAGs whose nodes are *blocks*
(partitions of datasets, Spark's "RDD blocks") and whose hyper-edges are
*tasks*. A task reads a set of input blocks — its *peer group* — and
materializes one output block. The all-or-nothing property (paper §II-C)
lives on peer groups: a task is sped up iff every materialized input is
cached.

Terminology is kept deliberately close to the paper:

* reference count (LRC, paper [10]): for a block ``b``, the number of
  *unmaterialized* blocks whose producing task reads ``b``.
* effective reference (paper Def. 2): a reference by task ``t`` is
  effective iff all of ``t``'s *materialized* input blocks are cached.
* peer group (paper §I): the input-block set of a task.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

BlockId = str
TaskId = str
JobId = str

_uid = itertools.count()


def fresh_id(prefix: str) -> str:
    return f"{prefix}_{next(_uid)}"


@dataclass(frozen=True)
class BlockMeta:
    """A partition of a dataset."""

    id: BlockId
    size: int                      # bytes
    dataset: str                   # logical dataset ("RDD") this block belongs to
    index: int                     # partition index within the dataset
    preferred_worker: Optional[int] = None  # data-locality hint


@dataclass(frozen=True)
class TaskSpec:
    """A compute task: reads ``inputs`` (its peer group), writes ``output``."""

    id: TaskId
    inputs: Tuple[BlockId, ...]
    output: BlockId
    job: JobId
    stage: int = 0
    compute_cost: float = 0.0      # abstract compute seconds (simulator)

    @property
    def peer_group(self) -> Tuple[BlockId, ...]:
        return self.inputs


class JobDAG:
    """A DAG of blocks and tasks; supports incremental multi-job composition.

    The driver-side view: built once per job submission from the pipeline
    lineage (Spark: ``DAGScheduler``), then handed to the cache manager /
    ``PeerTrackerMaster``.
    """

    def __init__(self) -> None:
        self.blocks: Dict[BlockId, BlockMeta] = {}
        self.tasks: Dict[TaskId, TaskSpec] = {}
        # block -> tasks that read it. Insertion-ordered dict used as an
        # ordered set: iteration matches the old list semantics, but
        # retirement (serve traffic: one per completed request chain
        # position) is O(1) instead of O(consumers).
        self.consumers: Dict[BlockId, Dict[TaskId, None]] = {}
        # block -> task that produces it (None for source blocks)
        self.producer: Dict[BlockId, TaskId] = {}
        self.jobs: Dict[JobId, Dict[TaskId, None]] = {}

    # ------------------------------------------------------------------ build
    def add_block(self, block: BlockMeta) -> BlockMeta:
        if block.id in self.blocks:
            raise ValueError(f"duplicate block {block.id}")
        self.blocks[block.id] = block
        self.consumers.setdefault(block.id, {})
        return block

    def add_source(self, dataset: str, index: int, size: int,
                   preferred_worker: Optional[int] = None) -> BlockMeta:
        return self.add_block(
            BlockMeta(id=f"{dataset}[{index}]", size=size, dataset=dataset,
                      index=index, preferred_worker=preferred_worker))

    def add_task(self, task: TaskSpec) -> TaskSpec:
        if task.id in self.tasks:
            raise ValueError(f"duplicate task {task.id}")
        for b in task.inputs:
            if b not in self.blocks:
                raise ValueError(f"task {task.id} reads unknown block {b}")
        if task.output not in self.blocks:
            raise ValueError(f"task {task.id} writes unknown block {task.output}")
        if task.output in self.producer:
            raise ValueError(f"block {task.output} already has a producer")
        self.tasks[task.id] = task
        self.producer[task.output] = task.id
        for b in task.inputs:
            self.consumers[b][task.id] = None
        self.jobs.setdefault(task.job, {})[task.id] = None
        return task

    def remove_task(self, tid: TaskId, remove_output: bool = False) -> TaskSpec:
        """Retire a task from the DAG (serve: a request chain's reference
        left the system). The caller is responsible for having settled the
        task's counter contributions first (``DagState.on_task_removed``)."""
        task = self.tasks.pop(tid)
        for b in task.inputs:
            consumers = self.consumers.get(b)
            if consumers is not None:
                consumers.pop(tid, None)
        self.producer.pop(task.output, None)
        job_tasks = self.jobs.get(task.job)
        if job_tasks is not None:
            job_tasks.pop(tid, None)
            if not job_tasks:
                del self.jobs[task.job]
        if remove_output:
            self.remove_block(task.output)
        return task

    def remove_block(self, block: BlockId) -> None:
        """Drop a block with no remaining producer or consumers."""
        if self.consumers.get(block):
            raise ValueError(f"block {block} still has consumers")
        if block in self.producer:
            raise ValueError(f"block {block} still has a producer")
        self.blocks.pop(block, None)
        self.consumers.pop(block, None)

    # ------------------------------------------------------------------ query
    def source_blocks(self) -> List[BlockId]:
        return [b for b in self.blocks if b not in self.producer]

    def peer_groups(self) -> Dict[TaskId, Tuple[BlockId, ...]]:
        return {t.id: t.inputs for t in self.tasks.values()}

    def topological_tasks(self) -> List[TaskSpec]:
        """Kahn's algorithm over the task graph (stable order)."""
        indeg: Dict[TaskId, int] = {}
        for t in self.tasks.values():
            indeg[t.id] = sum(1 for b in t.inputs if b in self.producer)
        ready = [tid for tid, d in sorted(indeg.items()) if d == 0]
        out: List[TaskSpec] = []
        ready_i = 0
        while ready_i < len(ready):
            tid = ready[ready_i]
            ready_i += 1
            task = self.tasks[tid]
            out.append(task)
            for consumer in self.consumers.get(task.output, []):
                indeg[consumer] -= 1
                if indeg[consumer] == 0:
                    ready.append(consumer)
        if len(out) != len(self.tasks):
            raise ValueError("cycle in task DAG")
        return out

    def validate(self) -> None:
        self.topological_tasks()  # raises on cycles


# --------------------------------------------------------------------------
# Mutable DAG state: which blocks exist where.  Shared by the cache manager,
# the policies and the coordination layer.
# --------------------------------------------------------------------------
@dataclass
class DagState:
    """Runtime state of a (multi-)job DAG.

    Maintains, incrementally and in O(degree) per event:

    * ``ref_count[b]``     — the LRC reference count (paper [10]).
    * ``eff_ref_count[b]`` — the LERC effective reference count (Def. 2).
    * per-task ``missing[t]`` — # of materialized-but-uncached inputs; a
      peer group is *complete* iff ``missing == 0`` (paper §III-C labels).
    """

    dag: JobDAG
    materialized: set = field(default_factory=set)   # computed at least once
    cached: set = field(default_factory=set)         # currently in memory
    ref_count: Dict[BlockId, int] = field(default_factory=dict)
    eff_ref_count: Dict[BlockId, int] = field(default_factory=dict)
    missing: Dict[TaskId, int] = field(default_factory=dict)
    done_tasks: set = field(default_factory=set)
    # eviction-key listeners (EvictionIndex instances): called with the
    # blocks whose ref/eff counters just changed, or None for "everything"
    key_listeners: List = field(default_factory=list, repr=False)

    def __post_init__(self) -> None:
        self.rebuild()

    # ------------------------------------------------------------- listeners
    def add_key_listener(self, fn) -> None:
        self.key_listeners.append(fn)

    def _notify(self, blocks: Optional[Iterable[BlockId]]) -> None:
        for fn in self.key_listeners:
            fn(blocks)

    # ---------------------------------------------------------------- derive
    def task_live(self, tid: TaskId) -> bool:
        """A task still *references* its inputs while its output is
        unmaterialized (paper: reference count counts unmaterialized
        dependents)."""
        return tid not in self.done_tasks

    def group_complete(self, tid: TaskId) -> bool:
        return self.missing.get(tid, 0) == 0

    def rebuild(self) -> None:
        """Recompute all counters from scratch (oracle; also used by property
        tests to cross-check the incremental updates)."""
        self.ref_count = {b: 0 for b in self.dag.blocks}
        self.eff_ref_count = {b: 0 for b in self.dag.blocks}
        self.missing = {}
        for t in self.dag.tasks.values():
            self.missing[t.id] = sum(
                1 for b in t.inputs
                if b in self.materialized and b not in self.cached)
        for t in self.dag.tasks.values():
            if not self.task_live(t.id):
                continue
            effective = self.group_complete(t.id)
            for b in t.inputs:
                self.ref_count[b] += 1
                if effective:
                    self.eff_ref_count[b] += 1
        self._notify(None)

    # ---------------------------------------------------------------- events
    def _set_group_effective(self, tid: TaskId, effective: bool) -> None:
        delta = 1 if effective else -1
        inputs = self.dag.tasks[tid].inputs
        for b in inputs:
            self.eff_ref_count[b] += delta
        self._notify(inputs)

    def on_materialized(self, block: BlockId, into_cache: bool = True) -> None:
        """A block was computed (or re-computed). New materialized blocks
        enter the cache unless ``into_cache`` is False (direct-to-disk)."""
        first = block not in self.materialized
        self.materialized.add(block)
        if into_cache:
            if block not in self.cached:
                self.cached.add(block)
                if not first:
                    # was materialized-on-disk: groups lose a missing member
                    self._dec_missing(block)
        else:
            if first:
                # materialized straight to disk: it is "missing" for peers
                self._inc_missing(block, newly_materialized=True)
        if first and into_cache:
            pass  # newly materialized & cached: missing counts unaffected

        producer = self.dag.producer.get(block)
        if producer is not None and producer not in self.done_tasks:
            self.on_task_done(producer)

    def _inc_missing(self, block: BlockId, newly_materialized: bool = False) -> None:
        for tid in self.dag.consumers.get(block, []):
            if not self.task_live(tid):
                continue
            was_complete = self.group_complete(tid)
            self.missing[tid] = self.missing.get(tid, 0) + 1
            if was_complete:
                self._set_group_effective(tid, False)

    def _dec_missing(self, block: BlockId) -> None:
        for tid in self.dag.consumers.get(block, []):
            if not self.task_live(tid):
                continue
            self.missing[tid] = self.missing.get(tid, 0) - 1
            if self.group_complete(tid):
                self._set_group_effective(tid, True)

    def on_evicted(self, block: BlockId) -> List[TaskId]:
        """Block dropped from memory (still materialized, on disk).

        Returns the peer groups that were *complete* before this eviction —
        exactly the set for which the paper's protocol must broadcast.
        """
        if block not in self.cached:
            return []
        self.cached.discard(block)
        flipped = [tid for tid in self.dag.consumers.get(block, [])
                   if self.task_live(tid) and self.group_complete(tid)]
        self._inc_missing(block)
        return flipped

    def on_loaded(self, block: BlockId) -> None:
        """Materialized block fetched back from disk into memory."""
        if block in self.cached or block not in self.materialized:
            return
        self.cached.add(block)
        self._dec_missing(block)

    def on_task_done(self, tid: TaskId) -> None:
        """Task finished: its output is materialized, so its references to
        its inputs are no longer counted (they are no longer references by
        an unmaterialized block)."""
        if tid in self.done_tasks:
            return
        effective = self.group_complete(tid)
        self.done_tasks.add(tid)
        inputs = self.dag.tasks[tid].inputs
        for b in inputs:
            self.ref_count[b] -= 1
            if effective:
                self.eff_ref_count[b] -= 1
        self._notify(inputs)

    def on_task_undone(self, tid: TaskId) -> None:
        """Inverse of ``on_task_done``: the task's output was *lost* (a
        crashed worker took it), so the task must re-run and its references
        to its inputs are live again. ``missing`` is recomputed from the
        sets — it was not maintained while the task sat in
        ``done_tasks``."""
        if tid not in self.done_tasks:
            return
        self.done_tasks.discard(tid)
        inputs = self.dag.tasks[tid].inputs
        self.missing[tid] = sum(
            1 for b in inputs
            if b in self.materialized and b not in self.cached)
        effective = self.missing[tid] == 0
        for b in inputs:
            self.ref_count[b] += 1
            if effective:
                self.eff_ref_count[b] += 1
        self._notify(inputs)

    def on_lost(self, block: BlockId) -> None:
        """Crash loss: the block left memory AND its materialization is
        gone — unlike ``on_evicted`` there is no disk copy to reload, so
        the producing task must re-run (lineage recompute). Consumers stop
        counting it as a *missing* member (an unmaterialized input is
        absent, not missing), and a done producer is resurrected."""
        self.on_evicted(block)
        if block not in self.materialized:
            return
        self.materialized.discard(block)
        # after the eviction above the block was materialized-but-uncached,
        # i.e. "missing" in every live consumer group; unmaterializing it
        # removes it from that count
        self._dec_missing(block)
        producer = self.dag.producer.get(block)
        if producer is not None and producer in self.done_tasks:
            self.on_task_undone(producer)

    def on_task_added(self, tid: TaskId) -> None:
        """Incremental counterpart of ``rebuild`` for one new task: charge
        its references (serve: a request chain arrived). O(group size)."""
        t = self.dag.tasks[tid]
        self.missing[tid] = sum(
            1 for b in t.inputs
            if b in self.materialized and b not in self.cached)
        effective = self.missing[tid] == 0
        for b in t.inputs:
            self.ref_count[b] = self.ref_count.get(b, 0) + 1
            if effective:
                self.eff_ref_count[b] = self.eff_ref_count.get(b, 0) + 1
            else:
                self.eff_ref_count.setdefault(b, 0)
        self._notify(t.inputs)

    def on_task_removed(self, tid: TaskId) -> None:
        """Retire a task entirely (serve: request finished or cancelled):
        settle its counter contributions and forget its bookkeeping. The
        caller may then drop it from the DAG (``JobDAG.remove_task``)."""
        self.on_task_done(tid)
        self.done_tasks.discard(tid)
        self.missing.pop(tid, None)

    def on_removed(self, block: BlockId) -> None:
        """Block deleted entirely (unpersisted): treated as eviction."""
        self.on_evicted(block)
        self.materialized.discard(block)

    def forget_block(self, block: BlockId) -> None:
        """Drop every trace of a block that no live task references (serve:
        radix-skeleton GC). The caller guarantees ``ref_count`` is zero, so
        no counters or group labels change — this only bounds the dicts."""
        self.cached.discard(block)
        self.materialized.discard(block)
        self.ref_count.pop(block, None)
        self.eff_ref_count.pop(block, None)

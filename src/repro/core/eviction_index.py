"""Incremental eviction index: a lazy min-heap over policy eviction keys.

The seed implementation re-sorted every in-memory block on each eviction
batch (core) or re-scanned every pending request chain per victim (serve).
This index makes victim selection O(log n) amortized:

* membership mirrors the set of evictable blocks (one index per cache);
* each member has one valid heap entry ``(eviction_key, seq, block)``,
  identified by its globally-unique ``seq``;
* when a block's key *may* have changed, the entry is invalidated by
  pushing a fresh entry (new seq) — superseded entries are skipped (and
  discounted) on pop;
* key-change notifications come from two producers: the owning ``Policy``
  (recency/frequency updates via ``on_insert``/``on_access``) and the
  shared ``DagState`` (reference-count and group-completeness flips,
  which it already computes in O(degree) per event).

Victim selection is therefore a sequence of heap pops against *current*
counters: popping k victims is equivalent to taking the first k blocks of
a full sort under the same keys (keys are not mutated during a batch), and
when the caller applies state updates between pops (the serve path), each
pop reflects every earlier eviction — identical to the brute-force
per-victim re-scan it replaces.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .dag import BlockId, DagState

# compact the heap when stale entries outnumber live ones by this margin
_COMPACT_SLACK = 64


class EvictionIndex:
    """Lazy min-heap keyed by ``policy.eviction_key`` with
    invalidate-on-update semantics."""

    def __init__(self, policy, state: DagState) -> None:
        self.policy = policy
        self.state = state
        self._heap: List[Tuple] = []     # (key, seq, block)
        # membership: block -> seq of its single valid heap entry. The seq
        # is globally unique, so an entry left behind by a discard can
        # never be mistaken for a later re-add's entry.
        self._entry: Dict[BlockId, int] = {}
        self._seq = itertools.count()
        self._stale = 0
        policy.attach_index(self)
        state.add_key_listener(self._on_keys_changed)

    # ------------------------------------------------------------ membership
    def __contains__(self, block: BlockId) -> bool:
        return block in self._entry

    def __len__(self) -> int:
        return len(self._entry)

    def add(self, block: BlockId) -> None:
        """Start tracking ``block`` (idempotent: re-adding invalidates)."""
        if block in self._entry:
            self._stale += 1
        seq = next(self._seq)
        self._entry[block] = seq
        heapq.heappush(self._heap,
                       (self.policy.eviction_key(block, self.state),
                        seq, block))
        self._maybe_compact()

    def discard(self, block: BlockId) -> None:
        """Stop tracking ``block`` (its heap entries become stale)."""
        if self._entry.pop(block, None) is not None:
            self._stale += 1
            self._maybe_compact()

    def invalidate(self, block: BlockId) -> None:
        """Note that ``block``'s eviction key may have changed."""
        if block in self._entry:
            self.add(block)

    # ---------------------------------------------------------- notifications
    def _on_keys_changed(self, blocks: Optional[Iterable[BlockId]]) -> None:
        """DagState listener; ``None`` means "everything changed"."""
        if blocks is None:
            self.rebuild()
        else:
            for b in blocks:
                self.invalidate(b)

    def rebuild(self) -> None:
        """Recompute every member's key (after ``DagState.rebuild``)."""
        members = list(self._entry)
        self._heap = []
        self._entry = {}
        self._stale = 0
        for b in members:
            seq = next(self._seq)
            self._entry[b] = seq
            self._heap.append((self.policy.eviction_key(b, self.state),
                               seq, b))
        heapq.heapify(self._heap)

    def _maybe_compact(self) -> None:
        if self._stale > len(self._entry) + _COMPACT_SLACK:
            self.rebuild()

    # ----------------------------------------------------------------- query
    def pop_min(self, exclude: Optional[Set[BlockId]] = None
                ) -> Optional[BlockId]:
        """Remove and return the member with the smallest current key, or
        None if every member is excluded. Excluded members stay tracked."""
        exclude = exclude or ()
        stash: List[Tuple] = []
        victim: Optional[BlockId] = None
        while self._heap:
            entry = heapq.heappop(self._heap)
            key, seq, block = entry
            if self._entry.get(block) != seq:
                self._stale -= 1
                continue
            if block in exclude:
                stash.append(entry)
                continue
            del self._entry[block]
            victim = block
            break
        # excluded entries were still valid (nothing mutated keys between
        # pop and re-push): restore them verbatim, no recomputation
        for entry in stash:
            heapq.heappush(self._heap, entry)
        return victim

    def choose_victims(self, needed: int, sizes: Dict[BlockId, int],
                       pinned: Optional[Set[BlockId]] = None
                       ) -> List[BlockId]:
        """Pop victims until ``needed`` bytes are covered (or the index is
        exhausted). Victims leave the index; the caller evicts them."""
        pinned = pinned or set()
        victims: List[BlockId] = []
        freed = 0
        while freed < needed:
            b = self.pop_min(exclude=pinned)
            if b is None:
                break
            victims.append(b)
            freed += sizes[b]
        return victims

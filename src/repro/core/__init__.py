"""repro.core — the paper's contribution: DAG-aware, peer-coordinated cache
management (LERC) with effective-cache-hit-ratio accounting."""
from .dag import BlockId, BlockMeta, DagState, JobDAG, TaskId, TaskSpec, fresh_id
from .block_store import CacheManager, DiskTier, MemoryTier
from .eviction_index import EvictionIndex
from .coordination import (MessageBus, PeerTracker, PeerTrackerMaster,
                           build_cluster)
from .metrics import CacheMetrics, MessageStats
from .policies import (LERC, LFU, LRC, LRU, MRU, FIFO, Belady, Policy,
                       Sticky, POLICIES, make_policy)

__all__ = [
    "BlockId", "BlockMeta", "DagState", "JobDAG", "TaskId", "TaskSpec",
    "fresh_id", "CacheManager", "DiskTier", "MemoryTier", "EvictionIndex",
    "MessageBus",
    "PeerTracker", "PeerTrackerMaster", "build_cluster", "CacheMetrics",
    "MessageStats", "LERC", "LFU", "LRC", "LRU", "MRU", "FIFO", "Belady",
    "Policy", "Sticky", "POLICIES", "make_policy",
]

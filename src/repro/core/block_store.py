"""Per-worker two-tier block store (memory cache + disk) and the
policy-driven cache manager.

The manager is the single mutation point for cache state: every insert /
access / evict flows through it so that (a) the ``DagState`` counters stay
exact, (b) metrics observe every access, and (c) the coordination layer
sees every completeness transition.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .dag import BlockId, DagState, JobDAG, TaskId
from .eviction_index import EvictionIndex
from .metrics import CacheMetrics
from .policies import Policy


@dataclass
class MemoryTier:
    capacity: int
    used: int = 0
    blocks: Dict[BlockId, int] = field(default_factory=dict)  # id -> bytes

    def __contains__(self, block: BlockId) -> bool:
        return block in self.blocks

    def put(self, block: BlockId, size: int) -> None:
        assert block not in self.blocks
        self.blocks[block] = size
        self.used += size

    def drop(self, block: BlockId) -> int:
        size = self.blocks.pop(block)
        self.used -= size
        return size

    @property
    def free(self) -> int:
        return self.capacity - self.used


@dataclass
class DiskTier:
    """Unbounded spill tier. In the simulator this is bandwidth-modelled; in
    ``repro.data`` it is a real directory of .npy files."""

    blocks: Dict[BlockId, int] = field(default_factory=dict)

    def __contains__(self, block: BlockId) -> bool:
        return block in self.blocks

    def put(self, block: BlockId, size: int) -> None:
        self.blocks[block] = size

    def drop(self, block: BlockId) -> None:
        self.blocks.pop(block, None)


class CacheManager:
    """Policy-pluggable cache manager for one logical cache.

    ``on_evict`` / ``on_load`` hooks let the embedding system (simulator,
    data pipeline, coordination protocol) observe transitions. ``pinned``
    blocks (inputs of currently-running tasks) are never evicted — matching
    Spark's unroll/pin semantics.
    """

    def __init__(self, capacity: int, policy: Policy, state: DagState,
                 metrics: Optional[CacheMetrics] = None,
                 on_evict: Optional[Callable[[BlockId, List[TaskId]], None]] = None,
                 on_load: Optional[Callable[[BlockId], None]] = None) -> None:
        self.mem = MemoryTier(capacity)
        self.disk = DiskTier()
        self.policy = policy
        self.state = state
        # incremental victim queue over this cache's in-memory blocks;
        # key invalidations flow in from the policy and the DagState
        self.index = EvictionIndex(policy, state)
        self.metrics = metrics or CacheMetrics()
        self.on_evict = on_evict
        self.on_load = on_load
        self.pinned: set = set()

    # ------------------------------------------------------------------ util
    def sizes(self) -> Dict[BlockId, int]:
        return self.mem.blocks

    def in_memory(self, block: BlockId) -> bool:
        return block in self.mem

    def pin(self, *blocks: BlockId) -> None:
        self.pinned.update(blocks)

    def unpin(self, *blocks: BlockId) -> None:
        self.pinned.difference_update(blocks)

    # ------------------------------------------------------------- mutations
    def _evict_for(self, needed: int) -> List[BlockId]:
        """Free at least ``needed`` bytes; returns victims in order."""
        if needed <= self.mem.free:
            return []
        victims = self.policy.choose_victims(
            list(self.mem.blocks), needed - self.mem.free,
            self.mem.blocks, self.state, pinned=self.pinned,
            index=self.index)
        for v in victims:
            self.evict(v)
        return victims

    def evict(self, block: BlockId) -> None:
        size = self.mem.drop(block)
        self.disk.put(block, size)
        self.index.discard(block)
        self.policy.on_remove(block)
        flipped_groups = self.state.on_evicted(block)
        self.metrics.evictions += 1
        if self.on_evict is not None:
            self.on_evict(block, flipped_groups)

    def insert(self, block: BlockId, size: int,
               materialized_now: bool = True) -> List[BlockId]:
        """Insert a newly materialized (or externally produced) block.

        Returns the victims evicted to make room. If the block is larger
        than the whole cache it goes straight to disk (Spark: unroll
        failure → disk store).
        """
        if block in self.mem:
            return []
        if size > self.mem.capacity:
            self.disk.put(block, size)
            if materialized_now:
                self.state.on_materialized(block, into_cache=False)
            return []
        victims = self._evict_for(size)
        self.mem.put(block, size)
        self.disk.drop(block)
        self.policy.on_insert(block)
        if materialized_now:
            self.state.on_materialized(block, into_cache=True)
        else:
            self.state.on_loaded(block)
        # index last: the key is computed against fully-updated counters
        self.index.add(block)
        return victims

    def load_from_disk(self, block: BlockId) -> List[BlockId]:
        """Promote a spilled block back into memory (after a miss)."""
        assert block in self.disk
        size = self.disk.blocks[block]
        victims = self.insert(block, size, materialized_now=False)
        if self.on_load is not None:
            self.on_load(block)
        return victims

    # ------------------------------------------------------------ task-level
    def access_task_inputs(self, task: TaskId) -> Dict[BlockId, bool]:
        """Record the cache accesses a task makes when it starts.

        Effectiveness is judged *at access time* against the whole peer
        group (paper Def. 1): a hit on ``b`` is effective iff every
        materialized peer of the task is in memory.

        Returns {block: was_hit}.
        """
        spec = self.state.dag.tasks[task]
        materialized_peers = [b for b in spec.inputs if b in self.state.materialized]
        all_peers_cached = all(b in self.mem for b in materialized_peers)
        # ineffective-hit attribution: where the first blocking peer sits
        # (on disk a load would complete the group; absent it must be
        # recomputed — "evicted" vs "never_cached" is not distinguishable
        # from MemoryTier/DiskTier membership alone, so absent blocks that
        # were never spilled attribute to the recompute bucket)
        cause = None
        if not all_peers_cached:
            blocker = next(b for b in materialized_peers if b not in self.mem)
            cause = "disk" if blocker in self.disk else "never_cached"
        hits: Dict[BlockId, bool] = {}
        for b in materialized_peers:
            hit = b in self.mem
            hits[b] = hit
            self.policy.on_access(b)
            self.metrics.record_access(hit=hit,
                                       effective=hit and all_peers_cached,
                                       cause=cause)
        return hits

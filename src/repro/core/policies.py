"""Cache replacement policies.

Recency/frequency policies (LRU, LFU, MRU, FIFO, LRFU) are DAG-oblivious;
LRC is DAG-aware (paper [10]); LERC (this paper) is DAG- and peer-aware;
Sticky is the paper's strawman (§III-A); Belady is the clairvoyant lower
bound used by the simulator for headroom analysis.

A policy ranks the *eviction preference* of in-memory blocks. The cache
manager asks for victims until enough bytes are free. All policies are
deterministic given their tiebreaks (insertion counter); LRC optionally
breaks ties uniformly at random, matching the paper's §II-C analysis of
wrong-block probability.
"""
from __future__ import annotations

import random
from abc import ABC, abstractmethod
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from .dag import BlockId, DagState


class Policy(ABC):
    """Ranks in-memory blocks for eviction. Lower key = evicted first.

    The coordination plane reads two protocol-level traits: ``uses_dag``
    (the policy's key reads lineage reference counts, so workers need the
    peer-information profile broadcast) and ``uses_completeness`` (the key
    reads peer-group completeness labels, so workers additionally run the
    paper's eviction report/broadcast protocol). DAG-oblivious policies
    ship neither — that difference is the measured LERC overhead.
    """

    name: str = "abstract"
    uses_dag: bool = False
    uses_completeness: bool = False

    def __init__(self) -> None:
        self._clock = 0
        self._last_access: Dict[BlockId, int] = {}
        self._freq: Dict[BlockId, int] = {}
        self._inserted_at: Dict[BlockId, int] = {}
        self._indexes: List = []      # EvictionIndexes fed by this policy

    # ----------------------------------------------------------------- index
    def attach_index(self, index) -> None:
        self._indexes.append(index)

    def _touch(self, block: BlockId) -> None:
        """This policy's own key inputs for ``block`` changed."""
        for index in self._indexes:
            index.invalidate(block)

    # ------------------------------------------------------------- lifecycle
    def on_insert(self, block: BlockId) -> None:
        self._clock += 1
        self._inserted_at[block] = self._clock
        self._last_access[block] = self._clock
        self._freq[block] = self._freq.get(block, 0)
        self._touch(block)

    def on_access(self, block: BlockId) -> None:
        self._clock += 1
        self._last_access[block] = self._clock
        self._freq[block] = self._freq.get(block, 0) + 1
        self._touch(block)

    def on_remove(self, block: BlockId) -> None:
        self._inserted_at.pop(block, None)

    # ------------------------------------------------------------------ rank
    @abstractmethod
    def eviction_key(self, block: BlockId, state: DagState):
        """Sort key: blocks with the smallest key are evicted first."""

    def choose_victims(self, candidates: Iterable[BlockId], needed: int,
                       sizes: Dict[BlockId, int], state: DagState,
                       pinned: Optional[set] = None,
                       index=None) -> List[BlockId]:
        """Victims covering ``needed`` bytes, best-first.

        With an ``EvictionIndex`` this is O(victims · log n); the sorted
        full scan remains as the index-less fallback (and as the oracle the
        property tests compare against).
        """
        if index is not None:
            return index.choose_victims(needed, sizes, pinned)
        pinned = pinned or set()
        ranked = sorted((b for b in candidates if b not in pinned),
                        key=lambda b: self.eviction_key(b, state))
        victims, freed = [], 0
        for b in ranked:
            if freed >= needed:
                break
            victims.append(b)
            freed += sizes[b]
        return victims


class LRU(Policy):
    name = "lru"

    def eviction_key(self, block: BlockId, state: DagState):
        return self._last_access.get(block, 0)


class MRU(Policy):
    name = "mru"

    def eviction_key(self, block: BlockId, state: DagState):
        return -self._last_access.get(block, 0)


class FIFO(Policy):
    name = "fifo"

    def eviction_key(self, block: BlockId, state: DagState):
        return self._inserted_at.get(block, 0)


class LFU(Policy):
    name = "lfu"

    def eviction_key(self, block: BlockId, state: DagState):
        return (self._freq.get(block, 0), self._last_access.get(block, 0))


class LRC(Policy):
    """Least Reference Count (paper [10]): evict the block with the fewest
    unmaterialized dependents. Ties: random (paper §II-C) or LRU."""

    name = "lrc"
    uses_dag = True

    def __init__(self, tiebreak: str = "lru", seed: int = 0) -> None:
        super().__init__()
        assert tiebreak in ("lru", "random")
        self.tiebreak = tiebreak
        self._rng = random.Random(seed)

    def eviction_key(self, block: BlockId, state: DagState):
        rc = state.ref_count.get(block, 0)
        if self.tiebreak == "random":
            return (rc, self._rng.random())
        return (rc, self._last_access.get(block, 0))


class LERC(Policy):
    """Least Effective Reference Count (THE paper's policy, §III-B).

    Evict the in-memory block with the smallest effective reference count —
    the number of unmaterialized dependents whose peer groups are entirely
    cached. Ties are broken by plain reference count (a block that speeds up
    nothing *now* may still be one peer-load away from usefulness), then by
    recency (LRU).
    """

    name = "lerc"
    uses_dag = True
    uses_completeness = True

    def eviction_key(self, block: BlockId, state: DagState):
        return (state.eff_ref_count.get(block, 0),
                state.ref_count.get(block, 0),
                self._last_access.get(block, 0))


class Sticky(Policy):
    """The paper's naive strawman (§III-A): peer groups stick together — if
    any peer of a group is uncached, the remaining members are eviction
    candidates of the lowest class, *regardless* of their other references.
    Inefficient when a block is shared across tasks (the paper's argument
    for LERC); kept as a baseline.
    """

    name = "sticky"
    uses_dag = True
    uses_completeness = True

    def eviction_key(self, block: BlockId, state: DagState):
        dag = state.dag
        in_broken_group = any(
            state.task_live(t) and not state.group_complete(t)
            for t in dag.consumers.get(block, []))
        live_refs = state.ref_count.get(block, 0)
        # broken-group members first; then fewest refs; then LRU
        return (0 if in_broken_group else 1, live_refs,
                self._last_access.get(block, 0))


class Belady(Policy):
    """Clairvoyant MIN/OPT: evict the block whose next access is farthest in
    the future. Requires the future access trace (the simulator provides
    it); blocks with no future access are evicted first.
    """

    name = "belady"

    def __init__(self) -> None:
        super().__init__()
        self._future: Dict[BlockId, Deque[int]] = {}
        self._cursor = 0

    def set_trace(self, trace: List[BlockId]) -> None:
        stale = set(self._future)        # keys from any previous trace
        self._future = {}
        for i, b in enumerate(trace):
            self._future.setdefault(b, deque()).append(i)
        self._cursor = 0
        for b in stale | set(self._future):
            self._touch(b)

    def advance(self, block: BlockId) -> None:
        """Consume one access of ``block`` from the trace."""
        self._cursor += 1
        accesses = self._future.get(block)
        if accesses:
            accesses.popleft()
            self._touch(block)

    def eviction_key(self, block: BlockId, state: DagState):
        accesses = self._future.get(block, [])
        nxt = accesses[0] if accesses else float("inf")
        return -nxt if nxt != float("inf") else float("-inf")


POLICIES = {
    "lru": LRU,
    "mru": MRU,
    "fifo": FIFO,
    "lfu": LFU,
    "lrc": LRC,
    "lerc": LERC,
    "sticky": Sticky,
    "belady": Belady,
}


def make_policy(name: str, **kwargs) -> Policy:
    try:
        return POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; have {sorted(POLICIES)}")

"""repro.obs — zero-overhead-when-off tracing/telemetry for the serve
and sim stacks. ``TraceRecorder`` is a bounded ring buffer of spans,
instants, counter samples and per-request async lifecycle events,
stamped with both the embedder's deterministic virtual clock and the
wall clock, exporting Chrome/Perfetto trace-event JSON. Wire it in with
``ServeEngine.attach_trace`` / ``ShardedFrontend.attach_trace`` /
``ClusterSim(trace=...)`` or ``repro.launch.serve --trace out.json``;
``benchmarks/trace_report.py`` renders reports from the export."""
from .trace import (TID_BUS, TID_ENGINE, TID_REQ, TID_SCHED, TID_STORE,
                    Span, TraceRecorder, jsonable)

__all__ = ["TraceRecorder", "Span", "jsonable", "TID_ENGINE", "TID_SCHED",
           "TID_STORE", "TID_REQ", "TID_BUS"]

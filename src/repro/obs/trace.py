"""Bounded ring-buffer trace recorder with Chrome/Perfetto export.

The serve/sim stack is instrumented at every layer — engine step phases,
scheduler decisions, per-request lifecycle, store events (evict / demote
/ promote with the policy's eviction key at decision time), coordination
bus messages — but ALL of it is off by default: instrumentation sites
are ``if trace is not None`` guards, so an engine without a recorder is
bit-identical to the pre-obs code (tested in
``tests/test_obs.py::test_tracing_off_bit_identity``).

Two clocks, stamped on every event:

* **virtual** — the embedder's deterministic clock (``ServeEngine.now``
  on the ``StepCostModel``, ``ClusterSim``'s event-loop clock). Units
  are the embedder's abstract milliseconds; reproducible on any host.
  Embedders keep ``recorder.vt`` current (or pass ``vt=`` explicitly for
  backdated events like arrivals).
* **wall** — ``time.perf_counter`` seconds since the recorder was built.
  What intra-step phase durations actually cost on this machine.

``export(timebase=...)`` picks which clock becomes the Chrome
trace-event ``ts``; the other is preserved per-event in ``args`` only
where the embedder put it there. The export is the standard JSON object
format (``{"traceEvents": [...]}``) with ``X`` (complete), ``i``
(instant), ``C`` (counter) and ``b``/``n``/``e`` (async lifecycle)
phases plus ``M`` process/thread-name metadata — loadable in
``ui.perfetto.dev`` / ``chrome://tracing`` as-is.

The buffer is a ``deque(maxlen=limit)``: under sustained traffic the
oldest events drop (``n_emitted`` still counts them) so memory stays
bounded; metadata labels live outside the ring and always export.
"""
from __future__ import annotations

import json
import math
import time
from collections import deque
from typing import Any, Dict, Optional

# thread-id lanes used by the serve engine's instrumentation (one pid per
# engine/shard, one lane per subsystem)
TID_ENGINE = 0
TID_SCHED = 1
TID_STORE = 2
TID_REQ = 3
TID_BUS = 4

_LANE_NAMES = {TID_ENGINE: "engine", TID_SCHED: "scheduler",
               TID_STORE: "store", TID_REQ: "requests", TID_BUS: "bus"}


def jsonable(obj):
    """Recursively coerce an object into strict-JSON-safe values: tuples
    and sets become lists, numpy scalars their Python values, non-finite
    floats strings (strict JSON has no Infinity/NaN — Perfetto rejects
    them), and anything else its ``str``."""
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else str(obj)
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [jsonable(v) for v in obj]
    item = getattr(obj, "item", None)           # numpy scalars
    if callable(item):
        try:
            return jsonable(item())
        except Exception:
            pass
    return str(obj)


class Span:
    """One ``X`` (complete) event, timed on BOTH clocks between
    ``begin()`` and ``end()``. Usable as a context manager or via the
    explicit begin/end pair (the engine's step phases interleave with
    control flow that a ``with`` block cannot wrap)."""

    __slots__ = ("rec", "name", "cat", "pid", "tid", "args", "_w0", "_v0")

    def __init__(self, rec: "TraceRecorder", name: str, cat: str,
                 pid: int, tid: int, args: Optional[dict]) -> None:
        self.rec, self.name, self.cat = rec, name, cat
        self.pid, self.tid, self.args = pid, tid, args

    def begin(self) -> "Span":
        self._w0 = self.rec.wall()
        self._v0 = self.rec.vt
        return self

    def end(self, args: Optional[dict] = None) -> None:
        rec = self.rec
        if args:
            self.args = {**(self.args or {}), **args}
        rec._push({"ph": "X", "name": self.name, "cat": self.cat,
                   "pid": self.pid, "tid": self.tid,
                   "wall": self._w0, "vt": self._v0,
                   "dur_wall": rec.wall() - self._w0,
                   "dur_vt": rec.vt - self._v0, "args": self.args})

    def __enter__(self) -> "Span":
        return self.begin()

    def __exit__(self, *exc) -> None:
        self.end()


class TraceRecorder:
    """Bounded recorder for spans, instants, counter samples and async
    (request-lifecycle) events. One recorder serves a whole deployment:
    engines/shards stamp their own ``pid``, subsystems their ``tid``
    lane."""

    def __init__(self, limit: int = 200_000) -> None:
        self.limit = int(limit)
        self.events: deque = deque(maxlen=self.limit)
        self.n_emitted = 0            # includes events the ring dropped
        self.vt = 0.0                 # embedder-maintained virtual clock
        self._t0 = time.perf_counter()
        self._meta: Dict[tuple, str] = {}   # (pid,) / (pid, tid) -> name

    # ------------------------------------------------------------- plumbing
    def wall(self) -> float:
        return time.perf_counter() - self._t0

    def _push(self, ev: dict) -> None:
        self.n_emitted += 1
        self.events.append(ev)

    @property
    def n_dropped(self) -> int:
        return self.n_emitted - len(self.events)

    def label(self, pid: int, name: str, tid: Optional[int] = None,
              tname: Optional[str] = None) -> None:
        """Name a process (engine/shard/bus) and optionally one of its
        lanes. Labels are not ring-buffered — they always export."""
        self._meta[(pid,)] = name
        if tid is not None:
            self._meta[(pid, tid)] = tname or _LANE_NAMES.get(tid, str(tid))

    # --------------------------------------------------------------- events
    def span(self, name: str, cat: str, pid: int = 0, tid: int = 0,
             args: Optional[dict] = None) -> Span:
        return Span(self, name, cat, pid, tid, args)

    def instant(self, name: str, cat: str, pid: int = 0, tid: int = 0,
                args: Optional[dict] = None,
                vt: Optional[float] = None) -> None:
        self._push({"ph": "i", "name": name, "cat": cat, "pid": pid,
                    "tid": tid, "wall": self.wall(),
                    "vt": self.vt if vt is None else vt, "args": args})

    def counter(self, name: str, pid: int, values: Dict[str, float],
                vt: Optional[float] = None) -> None:
        """One ``C`` sample: every key in ``values`` becomes a counter
        track under ``name``."""
        self._push({"ph": "C", "name": name, "cat": "counter", "pid": pid,
                    "tid": 0, "wall": self.wall(),
                    "vt": self.vt if vt is None else vt, "args": values})

    def complete(self, name: str, cat: str, pid: int = 0, tid: int = 0, *,
                 vt: float, dur: float, args: Optional[dict] = None) -> None:
        """Retrospective ``X`` event on the VIRTUAL clock — for embedders
        (the cluster sim) that learn a span's duration when it is
        scheduled, not by bracketing real work."""
        self._push({"ph": "X", "name": name, "cat": cat, "pid": pid,
                    "tid": tid, "wall": self.wall(), "vt": vt,
                    "dur_wall": 0.0, "dur_vt": dur, "args": args})

    # async lifecycle (b/n/e share name+cat+id — Chrome's legacy async
    # events, which Perfetto renders as one track per id)
    def begin_async(self, name: str, aid, cat: str, pid: int = 0,
                    tid: int = 0, args: Optional[dict] = None,
                    vt: Optional[float] = None) -> None:
        self._async(name, aid, cat, pid, tid, "b", args, vt)

    def async_instant(self, name: str, aid, cat: str, pid: int = 0,
                      tid: int = 0, args: Optional[dict] = None,
                      vt: Optional[float] = None) -> None:
        self._async(name, aid, cat, pid, tid, "n", args, vt)

    def end_async(self, name: str, aid, cat: str, pid: int = 0,
                  tid: int = 0, args: Optional[dict] = None,
                  vt: Optional[float] = None) -> None:
        self._async(name, aid, cat, pid, tid, "e", args, vt)

    def _async(self, name, aid, cat, pid, tid, ph, args, vt) -> None:
        self._push({"ph": ph, "name": name, "cat": cat, "pid": pid,
                    "tid": tid, "id": str(aid), "wall": self.wall(),
                    "vt": self.vt if vt is None else vt, "args": args})

    # --------------------------------------------------------------- export
    def export(self, path: Optional[str] = None, timebase: str = "wall"
               ) -> Dict[str, Any]:
        """Chrome trace-event JSON. ``timebase`` picks the ``ts`` clock:
        ``"wall"`` (seconds -> us; real phase durations) or ``"virtual"``
        (the embedder's deterministic clock, 1 unit -> 1ms -> 1000 us).
        Returns the document; writes it to ``path`` when given."""
        if timebase not in ("wall", "virtual"):
            raise ValueError(f"timebase must be wall|virtual, "
                             f"got {timebase!r}")
        wall_ts = timebase == "wall"

        def ts(ev):
            return ev["wall"] * 1e6 if wall_ts else ev["vt"] * 1e3

        out = []
        for key, name in sorted(self._meta.items(), key=lambda kv: kv[0]):
            if len(key) == 1:
                out.append({"ph": "M", "name": "process_name", "pid": key[0],
                            "tid": 0, "ts": 0, "args": {"name": name}})
            else:
                out.append({"ph": "M", "name": "thread_name", "pid": key[0],
                            "tid": key[1], "ts": 0, "args": {"name": name}})
        for ev in self.events:
            e = {"ph": ev["ph"], "name": ev["name"], "cat": ev["cat"],
                 "pid": ev["pid"], "tid": ev["tid"], "ts": ts(ev)}
            if ev["ph"] == "X":
                e["dur"] = (ev["dur_wall"] * 1e6 if wall_ts
                            else ev["dur_vt"] * 1e3)
            if ev["ph"] == "i":
                e["s"] = "t"
            if "id" in ev:
                e["id"] = ev["id"]
            if ev.get("args") is not None:
                e["args"] = jsonable(ev["args"])
            out.append(e)
        doc = {"traceEvents": out, "displayTimeUnit": "ms",
               "otherData": {"timebase": timebase,
                             "events_emitted": self.n_emitted,
                             "events_dropped": self.n_dropped}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc

"""Shared per-block quantization — the transcode kernels behind KV-cache
tiering (``serve.TieredKVStore``) and gradient compression
(``train.compression``).

One storage format = one ``QuantSpec``: symmetric scale-per-block
quantization into a 1-byte dtype (int8, or float8_e4m3fn via ``ml_dtypes``
— a hard jax dependency, nothing new is imported into the image). The
same math is exposed three ways so every layer reports identical numbers:

* **batched jnp kernels over pool-row layouts** — stacked chain blocks
  shaped ``(n, *lead, bt, KV, D)`` quantize with one f32 scale per
  ``(row, *lead)`` sub-block (per-layer per-block scales: the amax
  reduction runs over the trailing ``(bt, KV, D)`` axes only). These are
  plain traceable functions; ``serve.kv_pool`` fuses them into its own
  jitted gather/scatter so a transcoding demotion is ONE dispatch and only
  the narrow bytes (+ tiny scales) cross the host boundary.
* **numpy twins** (``*_np``) for host↔disk transcodes, where no device is
  involved.
* **per-tensor helpers** for the gradient path (one scale per tensor —
  exactly the 1-bit-Adam-family wire format ``train.compression`` always
  used).

``compression_ratio`` is the single source of truth for stored-bytes
accounting: it includes the f32 scale-array overhead and prices the
*actual* source dtype (bf16 sources compress 2x into int8, not the 4x a
f32-only formula would claim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# all-zero blocks quantize against this floor (q == 0 everywhere, and the
# dequantized block is exactly zero) — matches the historical gradient path
_EPS = 1e-12
SCALE_DTYPE = np.dtype(np.float32)


@dataclass(frozen=True)
class QuantSpec:
    """One symmetric quantized storage format.

    ``qmax`` is the largest representable magnitude after scaling (127 for
    int8; 448, the float8_e4m3fn max, for fp8). ``rt_bound`` bounds the
    round-trip error: ``|x - dequant(quant(x))| <= rt_bound * amax(block)``
    element-wise (int8: half a quantization step, 1/254; fp8 e4m3: half an
    ulp in the top binade, 16/448). Frozen and hashable so a spec can be a
    jit static argument."""

    name: str
    qmax: float
    dtype: np.dtype
    rt_bound: float

    @property
    def itemsize(self) -> int:
        return self.dtype.itemsize

    @property
    def is_int(self) -> bool:
        return np.issubdtype(self.dtype, np.integer)


INT8 = QuantSpec("int8", 127.0, np.dtype(np.int8), 1.0 / 254.0)
FP8 = QuantSpec("fp8", 448.0, np.dtype(ml_dtypes.float8_e4m3fn),
                16.0 / 448.0)

SPECS = {"int8": INT8, "fp8": FP8}


def get_spec(name: Union[str, QuantSpec, None]) -> Optional[QuantSpec]:
    """Resolve a CLI-style name to a spec; ``None``/``"none"`` -> None
    (lossless — every transcode path degrades to a plain copy)."""
    if name is None or isinstance(name, QuantSpec):
        return name
    key = name.lower()
    if key in ("none", ""):
        return None
    if key not in SPECS:
        raise ValueError(f"unknown quant format {name!r}; "
                         f"have {sorted(SPECS)} or 'none'")
    return SPECS[key]


# ---------------------------------------------------------------------------
# Batched block kernels (jnp — traceable, fused into callers' jits)
# ---------------------------------------------------------------------------

def _encode(y: jax.Array, spec: QuantSpec) -> jax.Array:
    """Scaled values -> storage dtype. |y| <= qmax by construction, so the
    fp8 cast never overflows (448 is exactly representable) and the int8
    round stays inside [-127, 127] up to the explicit clip."""
    if spec.is_int:
        return jnp.clip(jnp.round(y), -spec.qmax, spec.qmax) \
            .astype(spec.dtype)
    return y.astype(spec.dtype)


def quantize_blocks(x: jax.Array, spec: QuantSpec
                    ) -> Tuple[jax.Array, jax.Array]:
    """Quantize stacked chain blocks ``(n, *mid, bt, KV, D)`` with one f32
    scale per ``(n, *mid)`` sub-block. Returns ``(q, scales)`` where ``q``
    has ``x``'s shape in ``spec.dtype`` and ``scales`` drops the trailing
    three axes."""
    ax = (-3, -2, -1)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=ax, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / spec.qmax
    q = _encode(xf / scale, spec)
    return q, jnp.squeeze(scale, ax).astype(jnp.float32)


def dequantize_blocks(q: jax.Array, scales: jax.Array, dtype: Any
                      ) -> jax.Array:
    """Invert ``quantize_blocks``: scales broadcast back over the trailing
    ``(bt, KV, D)`` axes."""
    return (q.astype(jnp.float32)
            * scales[..., None, None, None]).astype(dtype)


# jitted entry points for callers without a jit of their own (tests, host
# tools). spec/dtype are static: one compiled specialization per format.
quantize_rows = jax.jit(quantize_blocks, static_argnames=("spec",))
dequantize_rows = jax.jit(dequantize_blocks, static_argnames=("dtype",))


# ---------------------------------------------------------------------------
# numpy twins (host <-> disk transcodes; no device in the loop)
# ---------------------------------------------------------------------------

def quantize_blocks_np(x: np.ndarray, spec: QuantSpec
                       ) -> Tuple[np.ndarray, np.ndarray]:
    ax = (-3, -2, -1)
    xf = np.asarray(x, np.float32)
    amax = np.max(np.abs(xf), axis=ax, keepdims=True)
    scale = np.maximum(amax, _EPS) / spec.qmax
    y = xf / scale
    if spec.is_int:
        q = np.clip(np.round(y), -spec.qmax, spec.qmax).astype(spec.dtype)
    else:
        q = y.astype(spec.dtype)
    return q, np.squeeze(scale, ax).astype(SCALE_DTYPE)


def dequantize_blocks_np(q: np.ndarray, scales: np.ndarray, dtype: Any
                         ) -> np.ndarray:
    return (np.asarray(q, np.float32)
            * np.asarray(scales, np.float32)[..., None, None, None]) \
        .astype(dtype)


def transcode_tree_np(blocks, scales, src_spec: Optional[QuantSpec],
                      dst_spec: Optional[QuantSpec], lossless_templates=None):
    """Re-encode a pytree of stacked blocks from one storage format to
    another (host→disk demotion to a narrower dtype). ``scales`` is the
    matching scales pytree (None when ``src_spec`` is None). Returns
    ``(blocks', scales')`` in ``dst_spec``'s format; same-format transcodes
    are the identity (no precision loss). For a quantized→lossless
    transcode the blocks dequantize to f32 and the destination pool's
    write cast lands them in its leaf dtype."""
    if src_spec == dst_spec:
        return blocks, scales
    if src_spec is not None:        # widen to f32 first
        blocks = jax.tree.map(
            lambda q, s: dequantize_blocks_np(q, s, np.float32),
            blocks, scales)
        scales = None
    if dst_spec is None:
        return blocks, None
    pairs = jax.tree.map(lambda b: quantize_blocks_np(b, dst_spec), blocks)
    is_pair = lambda t: isinstance(t, tuple)                      # noqa: E731
    return (jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair),
            jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair))


# ---------------------------------------------------------------------------
# Per-tensor helpers (gradient compression wire format)
# ---------------------------------------------------------------------------

def quantize_tensor(x: jax.Array, spec: QuantSpec = INT8
                    ) -> Tuple[jax.Array, jax.Array]:
    """Whole-tensor symmetric quantization (one scalar scale) — the
    gradient wire format. Numerics are bit-identical to the historical
    ``train.compression._quantize_int8``."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, _EPS) / spec.qmax
    return _encode(xf / scale, spec), scale


def dequantize_tensor(q: jax.Array, scale: jax.Array,
                      dtype: Any = jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Byte accounting
# ---------------------------------------------------------------------------

def compression_ratio(numel: int, src_dtype: Any,
                      spec: Optional[QuantSpec] = INT8,
                      n_scales: int = 1) -> float:
    """Stored-bytes ratio lossless/quantized for ``numel`` elements of
    ``src_dtype`` carried with ``n_scales`` f32 scales. This is the ONE
    formula train and serve both report: it prices the actual source
    dtype (bf16 -> int8 is 2x, not 4x) and charges the scale array.
    ``spec=None`` (lossless) is ratio 1."""
    if spec is None:
        return 1.0
    src = np.dtype(src_dtype).itemsize * numel
    return src / (spec.itemsize * numel + SCALE_DTYPE.itemsize * n_scales)

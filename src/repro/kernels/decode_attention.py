"""Flash-decoding Pallas TPU kernel: single-token attention against a long
KV cache, split over the KV length (split-K).

Decode attention is memory-bound: one query row must stream S·KV·D cache
bytes through the chip. The TPU adaptation of FlashDecoding
[arXiv:2311.01282] splits the KV length across the grid's innermost
dimension and carries the online-softmax state (m, l, acc) in VMEM — one
(1, bk)·(bk, D) matvec pair per step on the VPU/MXU, with the cache tile
streamed HBM→VMEM once. GQA queries of one KV head are processed together
as a (G, D) tile so the streamed K/V block is reused G times.

Grid ``(B, KV, nk)``; valid-length masking supports ragged per-row cache
fills (continuous batching).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, bk: int, nk: int, G: int, scale: float,
                   window: Optional[int], softcap: Optional[float]):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    valid_len = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bk)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
    mask = kpos < valid_len
    if window is not None:
        mask &= kpos > valid_len - 1 - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - safe_m), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - safe_m))
    m_scr[...] = m_new
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     block_k: int = 256,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D) one query per sequence; k, v: (B, S, KV, D) cache;
    valid_len: (B,) number of filled cache slots per row (the query is at
    position valid_len-1). Returns (B, H, D)."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_k, S)
    S_p = -(-S // bk) * bk
    if S_p != S:
        pad = ((0, 0), (0, S_p - S), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nk = S_p // bk

    qg = q.reshape(B, KV, G, D)
    kt = k.transpose(0, 2, 1, 3)                          # (B, KV, S, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _decode_kernel, bk=bk, nk=nk, G=G, scale=1.0 / float(np.sqrt(D)),
        window=window, softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid_len.astype(jnp.int32))
    return out.reshape(B, H, D)

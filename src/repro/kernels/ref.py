"""Pure-jnp oracles for every kernel — deliberately naive (token-by-token
recurrences, full attention matrices) and independent of both the Pallas
kernels and the models' chunked implementations, so a bug shared by an
optimized pair cannot cancel out."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D). Full-matrix fp32 softmax."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    ke = jnp.repeat(k, H // KV, axis=2)
    ve = jnp.repeat(v, H // KV, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        ke.astype(jnp.float32)) / np.sqrt(D)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      ve.astype(jnp.float32)).astype(q.dtype)


def rglru_ref(a, b):
    """Sequential oracle for h_t = a_t h_{t-1} + b_t. a,b: (B,T,W) fp32.
    Returns (y (B,T,W), h_last (B,W))."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0,
                              (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), h_last


def rwkv6_ref(r, k, v, logw, u):
    """Token-by-token WKV oracle.
    out_t = r_t (S_{t-1} + u ⊙ k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    r,k,v,logw: (B,T,H,N); u: (H,N). Returns (B,T,H,N) fp32."""
    rf, kf, vf, lw = (t.astype(jnp.float32) for t in (r, k, v, logw))
    uf = u.astype(jnp.float32)
    B, T, H, N = rf.shape

    def step(S, xs):
        rt, kt, vt, lwt = xs                       # (B,H,N)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         S + uf[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, out

    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, lw))
    _, outs = jax.lax.scan(step, S0, xs)
    return outs.transpose(1, 0, 2, 3)

"""Flash attention Pallas TPU kernel: GQA, causal/window, logit softcap.

TPU-native adaptation (not a CUDA port):

* Grid ``(B, H, nq, nk)`` with the KV dimension innermost ("arbitrary"
  semantics): the online-softmax carry (m, l, acc) lives in VMEM scratch
  and survives across the KV steps of one (b, h, iq) tile — the canonical
  TPU flash schedule (one MXU matmul pair per grid step).
* BlockSpecs tile Q/K/V straight from HBM into VMEM: ``(bq, d)`` query
  tiles and ``(bk, d)`` KV tiles, d padded to the 128-lane register width
  by the caller (ops.py). bq = bk = 128 aligns both MXU operands.
* Causal/window masking is positional (iota within the tile); fully-masked
  tiles are *skipped on the wire* by the index-map trick: their loads are
  re-pointed at tile 0 and the accumulation is gated by ``pl.when`` — the
  TPU grid is sequential per core, so skipping the FLOPs is what matters.
* GQA: the kernel receives K/V already indexed per query head
  (``h // group`` in the index_map) — no repeated KV materialization.

Validated on CPU in interpret mode against ``ref.py`` (tests/test_kernels).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, nk: int, causal: bool,
                  window: Optional[int], softcap: Optional[float],
                  kv_len: int, scale: float):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = iq * bq
    k0 = ik * bk
    # tile-level skip decision (traced; grid is sequential per core)
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k0 <= q0 + bq - 1            # below-diagonal tiles only
    if window is not None:
        relevant &= k0 + bk - 1 > q0 - window    # inside the band

    @pl.when(relevant)
    def attend():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - safe_m)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - safe_m))
        m_scr[...] = m_new
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = alpha * acc_scr[...] + pv

    @pl.when(ik == nk - 1)
    def finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D), H % KV == 0.
    Returns (B, Sq, H, D). Self-attention positions (Sq tail-aligned to
    Skv is not supported here; Sq == Skv)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    group = H // KV
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))
    Sq_p = -(-Sq // bq) * bq
    Skv_p = -(-Skv // bk) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        pad = ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nq, nk = Sq_p // bq, Skv_p // bk

    # (B, S, H, D) -> (B, H, S, D): heads become a parallel grid dim and
    # the (S, D) tile is MXU-layout friendly
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        softcap=softcap, kv_len=Skv, scale=1.0 / float(np.sqrt(D)))

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m: running row max
            pltpu.VMEM((bq, 1), jnp.float32),   # l: running row sum
            pltpu.VMEM((bq, D), jnp.float32),   # acc: unnormalized output
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq] if Sq_p != Sq else out

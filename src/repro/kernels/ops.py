"""Jitted public entry points for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel
body executes as traced jnp ops, validating the exact tiling/masking logic
the TPU grid would run. On TPU backends ``interpret=False`` compiles the
real Mosaic kernels. The switch is automatic via ``jax.default_backend()``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .paged_attention import paged_decode_attention as _paged_decode
from .rglru_scan import rglru_scan_kernel as _rglru
from .rwkv6_scan import rwkv6_chunked_kernel as _rwkv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128):
    """Flash attention with GQA / sliding window / logit softcap.
    q: (B,S,H,D); k,v: (B,S,KV,D) -> (B,S,H,D)."""
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k,
                  interpret=_interpret())


@partial(jax.jit, static_argnames=("block_t", "block_w"))
def rglru_scan(a, b, *, block_t: int = 256, block_w: int = 512):
    """RG-LRU recurrence h_t = a_t h_{t-1} + b_t. a,b: (B,T,W).
    Returns (y (B,T,W) fp32, h_last (B,W))."""
    return _rglru(a, b, block_t=block_t, block_w=block_w,
                  interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def rwkv6_wkv(r, k, v, logw, u, *, chunk: int = 32):
    """RWKV6 WKV mixing. r,k,v,logw: (B,T,H,N); u: (H,N) -> (B,T,H,N)."""
    return _rwkv(r, k, v, logw, u, chunk=chunk, interpret=_interpret())


@partial(jax.jit, static_argnames=("window", "softcap", "block_k"))
def decode_attention(q, k, v, valid_len, *, window: Optional[int] = None,
                     softcap: Optional[float] = None, block_k: int = 256):
    """Flash-decoding: one query per row against a (B,S,KV,D) cache with
    per-row valid lengths. q: (B,H,D) -> (B,H,D)."""
    return _decode(q, k, v, valid_len, window=window, softcap=softcap,
                   block_k=block_k, interpret=_interpret())


@partial(jax.jit, static_argnames=("softcap",))
def paged_decode_attention(q, k_pages, v_pages, tables, qpos, *,
                           softcap: Optional[float] = None):
    """Paged flash-decoding: an (B,S,H,D) query chunk against KV pool
    pages (num_blocks,bt,KV,D) addressed by per-sequence block tables
    (B,NW); query (b,j) attends logical positions <= qpos[b,j]."""
    return _paged_decode(q, k_pages, v_pages, tables, qpos,
                         softcap=softcap, interpret=_interpret())

"""repro.kernels — Pallas TPU kernels for the compute hot spots: flash
attention (GQA/window/softcap), flash-decoding (plain and paged — the
latter streams K/V tiles straight from the serving tier's KV block pool
via scalar-prefetched block tables), RG-LRU scan, RWKV6 chunked WKV. Each
has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes via interpret
mode."""
from .ops import (decode_attention, flash_attention, paged_decode_attention,
                  rglru_scan, rwkv6_wkv)

__all__ = ["decode_attention", "flash_attention", "paged_decode_attention",
           "rglru_scan", "rwkv6_wkv"]

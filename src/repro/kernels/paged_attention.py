"""Paged flash-decoding Pallas TPU kernel: attention straight out of the
serving tier's KV block pool, indexed by per-sequence block tables.

The serving data plane stores KV in a paged pool — per layer, one buffer
shaped ``(num_blocks, block_tokens, KV, D)`` whose rows belong to prefix
chains, not slots. This kernel extends the flash-decoding split-K scheme
(``kernels/decode_attention.py``): the grid's innermost dimension walks a
sequence's *block table* instead of a contiguous cache, and the table is a
scalar-prefetch operand so each K/V tile's pool row is resolved before the
DMA issues — K/V stream HBM→VMEM directly from their pool rows, with no
gather materializing a contiguous cache view anywhere.

Two generalizations over plain flash-decoding:

* **Chunked queries** — ``S`` query tokens per sequence share the streamed
  K/V tile (they are processed as an ``(S*G, D)`` tile, so GQA packing and
  chunking compose); masking is per query *position* (``kpos <= qpos``),
  which subsumes valid-length masking, per-token causality inside a
  prefill chunk, and right-padded rows whose outputs the caller discards.
* **Logical positions** — block ``i`` of a table covers logical positions
  ``[i*bt, (i+1)*bt)`` regardless of which pool row backs it, so the
  kernel never sees (and the engine never computes) a contiguous layout.

Grid ``(B, KV, num_table_blocks)``. On non-TPU backends the interpret mode
runs the identical tiling/masking logic as traced jnp ops.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tbl_ref, q_ref, k_ref, v_ref, qpos_ref, o_ref, m_scr,
                  l_scr, acc_scr, *, bt: int, nw: int, G: int, S: int,
                  scale: float, softcap: Optional[float]):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (S*G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bt, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (S*G, bt)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    # logical positions of this tile's keys — the table block index, not
    # the pool row, carries position
    kpos = ik * bt + jax.lax.broadcasted_iota(jnp.int32, (S * G, bt), 1)
    qp = qpos_ref[0]                                      # (S,)
    qp = jax.lax.broadcast_in_dim(qp, (S, G), (0,)).reshape(S * G)
    mask = kpos <= qp[:, None]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - safe_m), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - safe_m))
    m_scr[...] = m_new
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nw - 1)
    def finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, tables: jax.Array,
                           qpos: jax.Array, *,
                           softcap: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D) query chunk per sequence (S=1 for plain decode);
    k_pages, v_pages: (num_blocks, bt, KV, D) pool pages; tables: (B, NW)
    int32 pool rows in chain order (block i of row b covers logical
    positions [i*bt, (i+1)*bt)); qpos: (B, S) absolute position of each
    query token. Query (b, j) attends to logical positions
    ``kpos <= qpos[b, j]``. Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    bt, KV = k_pages.shape[1], k_pages.shape[2]
    NW = tables.shape[1]
    G = H // KV

    # (B, KV, S*G, D): queries of one KV head share the streamed K/V tile
    qg = q.reshape(B, S, KV, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, KV, S * G, D)

    kernel = functools.partial(
        _paged_kernel, bt=bt, nw=NW, G=G, S=S,
        scale=1.0 / float(np.sqrt(D)), softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # the block table
        grid=(B, KV, NW),
        in_specs=[
            pl.BlockSpec((1, 1, S * G, D),
                         lambda b, h, ik, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, D),
                         lambda b, h, ik, tbl: (tbl[b, ik], 0, h, 0)),
            pl.BlockSpec((1, bt, 1, D),
                         lambda b, h, ik, tbl: (tbl[b, ik], 0, h, 0)),
            pl.BlockSpec((1, S), lambda b, h, ik, tbl: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, S * G, D),
                               lambda b, h, ik, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((S * G, 1), jnp.float32),
            pltpu.VMEM((S * G, 1), jnp.float32),
            pltpu.VMEM((S * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, S * G, D), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), qg, k_pages, v_pages,
      qpos.astype(jnp.int32))
    return out.reshape(B, KV, S, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, D)

"""RG-LRU linear-recurrence Pallas TPU kernel (Griffin/RecurrentGemma).

Why a kernel: XLA lowers ``jax.lax.associative_scan`` to a log-depth tree —
O(S log S) work and multiple HBM passes over the (B, S, W) sequence. The
recurrence ``h_t = a_t * h_{t-1} + b_t`` is elementwise over W, so a single
sequential VMEM pass does O(S) work with one read of (a, b) and one write
of h per element: this kernel is HBM-bandwidth-bound at exactly one
read+write per element — the roofline optimum for the op.

Schedule: grid ``(B, nW, nT)``, T innermost ("arbitrary"): the running
state (1, bw) lives in VMEM scratch across T tiles of one (b, iw) stripe.
Tiles are (bt, bw) with bw a multiple of the 128-lane width; rows step
through the VPU one at a time (a vector FMA per row).

Gate/projection matmuls stay outside (XLA/MXU); the kernel owns only the
scan, mirroring how the Griffin paper splits the block on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, y_ref, h_scr, *, bt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (bt, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(i, h):
        h = a[i] * h + b[i]                   # (bw,) VPU FMA
        y_ref[0, i] = h.astype(y_ref.dtype)
        return h

    h_scr[0] = jax.lax.fori_loop(0, bt, step, h_scr[0])


def rglru_scan_kernel(a: jax.Array, b: jax.Array, *, block_t: int = 256,
                      block_w: int = 512, interpret: bool = False):
    """a, b: (B, T, W) — decay and input of h_t = a_t h_{t-1} + b_t.
    Returns (y (B, T, W) fp32, h_last (B, W) fp32)."""
    B, T, W = a.shape
    bt = min(block_t, T)
    bw = min(block_w, W)
    T_p = -(-T // bt) * bt
    if T_p != T:
        # pad with identity steps: a=1, b=0 preserve the state
        a = jnp.pad(a, ((0, 0), (0, T_p - T), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, T_p - T), (0, 0)))
    assert W % bw == 0, (W, bw)
    nt, nw = T_p // bt, W // bw

    y = pl.pallas_call(
        functools.partial(_rglru_kernel, bt=bt),
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda ib, iw, it: (ib, it, iw)),
            pl.BlockSpec((1, bt, bw), lambda ib, iw, it: (ib, it, iw)),
        ],
        out_specs=pl.BlockSpec((1, bt, bw), lambda ib, iw, it: (ib, it, iw)),
        out_shape=jax.ShapeDtypeStruct((B, T_p, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b)
    y = y[:, :T] if T_p != T else y
    return y, y[:, -1, :]

"""RWKV6 (Finch) chunked WKV Pallas TPU kernel.

The WKV recurrence with data-dependent per-channel decay w_t:

    out_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ) ;  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

TPU adaptation: the chunked linear-attention form (as in the Finch paper's
CUDA kernel, re-blocked for the MXU). Per (b, h) head the kernel walks T in
chunks of C tokens, carrying the (N, N) state in VMEM scratch; each chunk
does three (C,N)x(N,C|N,N) MXU matmuls (intra scores, intra output, inter
output) plus the rank-C state update — all operands VMEM-resident. Exponent
shifts (per-chunk ``a0``) keep every exp() bounded, matching ref.py.

Grid ``(B, H, nc)`` with the chunk dim innermost (sequential); C=32 and
N≤256 keep the working set ≈ C·N·5·4B + N²·4B ≈ 0.4 MB ≪ VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
                 C: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, :, 0].astype(jnp.float32)        # (C, N)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    lw = lw_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # (1, N) -> broadcast
    S = s_scr[...]                                # (N, N)

    lc = jnp.cumsum(lw, axis=0)                   # inclusive log decay
    lce = lc - lw                                 # exclusive
    a0 = lc[0:1]                                  # per-chunk shift (1, N)
    q_in = r * jnp.exp(lce - a0)                  # bounded exponents
    k_in = k * jnp.exp(a0 - lc)

    # intra-chunk: strict lower triangle + current-token bonus u
    scores = jax.lax.dot_general(q_in, k_in, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    scores = jnp.where(tj < ti, scores, 0.0)
    out = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * (u * k), axis=1, keepdims=True)      # (C, 1)
    out = out + bonus * v
    # inter-chunk: contributions of the carried state
    out = out + jax.lax.dot_general(q_in * jnp.exp(a0), S,
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    o_ref[0, :, 0] = out.astype(o_ref.dtype)

    # state update: S <- diag(exp(lc_last)) S + sum_j k_j exp(lc_last-lc_j) v_j^T
    last = lc[-1:]                                # (1, N)
    k_out = k * jnp.exp(last - lc)                # (C, N)
    s_scr[...] = (jnp.exp(last).T * S
                  + jax.lax.dot_general(k_out, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))


def rwkv6_chunked_kernel(r, k, v, logw, u, *, chunk: int = 32,
                         interpret: bool = False):
    """r,k,v,logw: (B, T, H, N); u: (H, N). Returns wkv (B, T, H, N) fp32.
    T must be a multiple of ``chunk`` (callers pad; logw pad value 0 and
    k pad 0 keep the state invariant)."""
    B, T, H, N = r.shape
    C = min(chunk, T)
    T_p = -(-T // C) * C
    if T_p != T:
        pad = ((0, 0), (0, T_p - T), (0, 0), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        logw = jnp.pad(logw, pad)
    nc = T_p // C

    out = pl.pallas_call(
        functools.partial(_rwkv_kernel, C=C),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, C, 1, N), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, C, 1, N), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, C, 1, N), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, C, 1, N), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, N), lambda b, h, ic: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, 1, N), lambda b, h, ic: (b, ic, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T_p, H, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return out[:, :T] if T_p != T else out

"""Lineage-DAG data pipeline with a LERC-managed block cache.

This is the paper's mechanism embedded in a *real* input pipeline: every
transform declares its lineage, multi-input transforms (``zip_``,
``coalesce``) create peer groups, and the executor runs tasks against a
``CacheManager`` whose eviction policy is pluggable (LRU/LRC/LERC/...).
Evicted blocks spill to disk (real ``.npy`` I/O); a cache miss re-reads
them — so the effective-cache-hit ratio measured here maps directly onto
bytes NOT re-read from disk, the paper's Fig. 3 mechanism.

On a TPU training cluster there is one executor per host feeding that
host's device slice; ``repro.data.loader`` adds sharding/prefetch/resume.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import (BlockMeta, CacheManager, CacheMetrics, DagState, JobDAG,
                    TaskSpec, make_policy)


@dataclass(frozen=True)
class DataRef:
    """A logical dataset inside a pipeline: ``n_blocks`` partitions."""

    dataset: str
    n_blocks: int

    def block_id(self, i: int) -> str:
        return f"{self.dataset}[{i}]"


class Pipeline:
    """Builds the lineage DAG. Transforms are lazy; ``Executor`` runs them."""

    def __init__(self, name: str = "pipe") -> None:
        self.name = name
        self.dag = JobDAG()
        self._sources: Dict[str, List[np.ndarray]] = {}
        self._fns: Dict[str, Callable[..., np.ndarray]] = {}
        self._counter = 0

    def _fresh(self, kind: str) -> str:
        self._counter += 1
        return f"{self.name}.{kind}{self._counter}"

    # ------------------------------------------------------------- builders
    def source(self, arrays: Sequence[np.ndarray],
               name: Optional[str] = None) -> DataRef:
        """Materialized source partitions (rows of a dataset)."""
        ds = name or self._fresh("src")
        ref = DataRef(ds, len(arrays))
        self._sources[ds] = list(arrays)
        for i, a in enumerate(arrays):
            self.dag.add_block(BlockMeta(ref.block_id(i), a.nbytes, ds, i))
        return ref

    def map(self, ref: DataRef, fn: Callable[[np.ndarray], np.ndarray],
            name: Optional[str] = None,
            out_bytes_factor: float = 1.0) -> DataRef:
        ds = name or self._fresh("map")
        out = DataRef(ds, ref.n_blocks)
        for i in range(ref.n_blocks):
            src = self.dag.blocks[ref.block_id(i)]
            self.dag.add_block(BlockMeta(
                out.block_id(i), max(1, int(src.size * out_bytes_factor)),
                ds, i))
            tid = f"{ds}.t[{i}]"
            self.dag.add_task(TaskSpec(tid, (ref.block_id(i),),
                                       out.block_id(i), job=self.name))
            self._fns[tid] = fn
        return out

    def zip_(self, refs: Sequence[DataRef],
             fn: Callable[..., np.ndarray],
             name: Optional[str] = None) -> DataRef:
        """Multi-input transform: block i of every ref forms a PEER GROUP
        (the paper's all-or-nothing unit)."""
        n = refs[0].n_blocks
        assert all(r.n_blocks == n for r in refs)
        ds = name or self._fresh("zip")
        out = DataRef(ds, n)
        for i in range(n):
            size = sum(self.dag.blocks[r.block_id(i)].size for r in refs)
            self.dag.add_block(BlockMeta(out.block_id(i), size, ds, i))
            tid = f"{ds}.t[{i}]"
            self.dag.add_task(TaskSpec(
                tid, tuple(r.block_id(i) for r in refs), out.block_id(i),
                job=self.name))
            self._fns[tid] = fn
        return out

    def coalesce(self, ref: DataRef, factor: int,
                 fn: Optional[Callable[..., np.ndarray]] = None,
                 name: Optional[str] = None) -> DataRef:
        """Merge ``factor`` consecutive blocks into one (peer group of
        ``factor``)."""
        assert ref.n_blocks % factor == 0
        ds = name or self._fresh("coalesce")
        out = DataRef(ds, ref.n_blocks // factor)
        fn = fn or (lambda *xs: np.concatenate(xs))
        for i in range(out.n_blocks):
            inputs = tuple(ref.block_id(i * factor + j)
                           for j in range(factor))
            size = sum(self.dag.blocks[b].size for b in inputs)
            self.dag.add_block(BlockMeta(out.block_id(i), size, ds, i))
            tid = f"{ds}.t[{i}]"
            self.dag.add_task(TaskSpec(tid, inputs, out.block_id(i),
                                       job=self.name))
            self._fns[tid] = fn
        return out


@dataclass
class ExecStats:
    disk_reads: int = 0
    disk_read_bytes: int = 0
    disk_writes: int = 0
    recomputes: int = 0
    tasks_run: int = 0
    io_seconds: float = 0.0


class Executor:
    """Runs pipeline tasks against a policy-managed two-tier block store.

    * in-memory tier: ``{block_id: np.ndarray}`` bounded by ``cache_bytes``
      and managed by the chosen eviction policy,
    * disk tier: ``spill_dir/<block>.npy`` — written on first eviction,
      re-read (with real file I/O) on a subsequent miss.
    """

    def __init__(self, pipe: Pipeline, cache_bytes: int,
                 policy: str = "lerc", spill_dir: Optional[str] = None,
                 policy_kwargs: Optional[dict] = None) -> None:
        self.pipe = pipe
        self.state = DagState(pipe.dag)
        self.metrics = CacheMetrics()
        self.policy = make_policy(policy, **(policy_kwargs or {}))
        self.mgr = CacheManager(cache_bytes, self.policy, self.state,
                                metrics=self.metrics,
                                on_evict=self._spill)
        self.spill_dir = spill_dir or os.path.join(
            os.environ.get("TMPDIR", "/tmp"), f"repro_spill_{id(self)}")
        os.makedirs(self.spill_dir, exist_ok=True)
        self._mem: Dict[str, np.ndarray] = {}
        self.stats = ExecStats()

    # --------------------------------------------------------------- tiers
    def _path(self, block: str) -> str:
        safe = block.replace("/", "_").replace("[", "_").replace("]", "")
        return os.path.join(self.spill_dir, f"{safe}.npy")

    def _spill(self, block: str, flipped_groups) -> None:
        arr = self._mem.pop(block, None)
        if arr is None:
            return
        path = self._path(block)
        if not os.path.exists(path):
            t0 = time.perf_counter()
            np.save(path, arr)
            self.stats.io_seconds += time.perf_counter() - t0
            self.stats.disk_writes += 1

    def _fetch(self, block: str) -> np.ndarray:
        """Block value, loading from disk / recomputing lineage on miss."""
        if block in self._mem:
            return self._mem[block]
        path = self._path(block)
        if os.path.exists(path):
            t0 = time.perf_counter()
            arr = np.load(path)
            self.stats.io_seconds += time.perf_counter() - t0
            self.stats.disk_reads += 1
            self.stats.disk_read_bytes += arr.nbytes
            self.mgr.load_from_disk(block)
            self._mem[block] = arr
            return arr
        # source block never materialized: read from the pipeline source
        meta = self.pipe.dag.blocks[block]
        if meta.dataset in self.pipe._sources:
            arr = self.pipe._sources[meta.dataset][meta.index]
            return arr  # stable storage: not cache-managed
        # lineage recompute (lost intermediate — e.g. spill file removed)
        self.stats.recomputes += 1
        producer = self.pipe.dag.producer[block]
        return self._run_task(producer)

    # --------------------------------------------------------------- tasks
    def _run_task(self, tid: str) -> np.ndarray:
        spec = self.pipe.dag.tasks[tid]
        self.mgr.pin(*spec.inputs)
        try:
            self.mgr.access_task_inputs(tid)       # hit/effective metrics
            args = [self._fetch(b) for b in spec.inputs]
        finally:
            self.mgr.unpin(*spec.inputs)
        out = self.pipe._fns[tid](*args)
        self.stats.tasks_run += 1
        self._insert(spec.output, out)
        self.state.on_task_done(tid)
        return out

    def _insert(self, block: str, arr: np.ndarray) -> None:
        self._mem[block] = arr
        victims = self.mgr.insert(block, arr.nbytes)
        # (victims already spilled via the on_evict hook)

    # ----------------------------------------------------------------- api
    def load_sources(self, ref: DataRef) -> None:
        """Materialize source partitions into the cache (ingest stage)."""
        for i in range(ref.n_blocks):
            b = ref.block_id(i)
            if b not in self._mem and not self.mgr.in_memory(b):
                arr = self.pipe._sources[ref.dataset][i]
                self._insert(b, arr)
                self.state.on_materialized(b, into_cache=True)

    def materialize(self, ref: DataRef) -> List[np.ndarray]:
        """Run every task needed to produce ``ref``, in topological order."""
        needed = {ref.block_id(i) for i in range(ref.n_blocks)}
        for task in self.pipe.dag.topological_tasks():
            if task.id in self.state.done_tasks:
                continue
            self._run_task(task.id)
        return [self._fetch(b) for b in sorted(
            needed, key=lambda b: self.pipe.dag.blocks[b].index)]

    def get(self, ref: DataRef, i: int) -> np.ndarray:
        b = ref.block_id(i)
        producer = self.pipe.dag.producer.get(b)
        if producer is not None and producer not in self.state.done_tasks:
            return self._run_task(producer)
        return self._fetch(b)

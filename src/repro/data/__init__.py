"""repro.data — lineage-DAG pipeline with LERC block cache + per-host
training loader (shard/prefetch/resume/work-stealing)."""
from .loader import LoaderConfig, SyntheticTokenSource, TrainLoader
from .pipeline import DataRef, ExecStats, Executor, Pipeline

__all__ = ["LoaderConfig", "SyntheticTokenSource", "TrainLoader",
           "DataRef", "ExecStats", "Executor", "Pipeline"]

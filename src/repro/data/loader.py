"""Per-host training loader: deterministic sharding, prefetch, resume,
and work-stealing straggler mitigation.

Determinism contract: batch content is a pure function of
``(seed, step, host_id, n_hosts)`` — restarting from a checkpoint at step
k replays exactly the batches k, k+1, ... regardless of how many times the
process died in between (tests/test_data.py proves bitwise equality).

Straggler mitigation: block preparation fans out over a small thread pool
with a shared work queue — a slow block (cold cache, disk re-read) never
blocks its siblings; idle workers steal the remaining work. Prefetch keeps
``prefetch_depth`` batches ahead of the training loop.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np


@dataclass(frozen=True)
class LoaderConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    prefetch_depth: int = 2
    n_workers: int = 2          # block-preparation threads (work stealing)


class SyntheticTokenSource:
    """Deterministic synthetic corpus: block ``i`` is a pure function of
    (seed, i). Stands in for a tokenized shard on NFS/GCS; the LERC cache
    sits between this and the device feed (examples/train_lm.py)."""

    def __init__(self, vocab: int, block_tokens: int, seed: int = 0) -> None:
        self.vocab = vocab
        self.block_tokens = block_tokens
        self.seed = seed

    def block(self, i: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, i))
        return rng.integers(0, self.vocab, self.block_tokens,
                            dtype=np.int32)


class TrainLoader:
    """Yields {tokens, targets} host-local batches.

    ``fetch_block(step, slot)`` is pluggable so the LERC-managed pipeline
    executor can sit underneath (examples/train_lm.py wires that up); the
    default reads the synthetic source directly.
    """

    def __init__(self, cfg: LoaderConfig,
                 fetch_block: Optional[Callable[[int, int], np.ndarray]]
                 = None) -> None:
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self.source = SyntheticTokenSource(cfg.vocab,
                                           (cfg.seq_len + 1), cfg.seed)
        self._fetch = fetch_block or self._default_fetch
        self._queue: "queue.Queue" = queue.Queue(cfg.prefetch_depth)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._next_step = 0

    # ---------------------------------------------------------------- state
    def state_dict(self) -> Dict:
        return {"next_step": self._next_step}

    def load_state_dict(self, state: Dict) -> None:
        self._next_step = int(state["next_step"])

    # --------------------------------------------------------------- blocks
    def _global_slot(self, step: int, slot: int) -> int:
        """Unique block index for (step, row-of-global-batch)."""
        return step * self.cfg.global_batch \
            + self.cfg.host_id * self.local_batch + slot

    def _default_fetch(self, step: int, slot: int) -> np.ndarray:
        return self.source.block(self._global_slot(step, slot))

    def build_batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for ``step`` (work-stealing thread pool)."""
        rows: List[Optional[np.ndarray]] = [None] * self.local_batch
        work: "queue.Queue" = queue.Queue()
        for s in range(self.local_batch):
            work.put(s)
        errors: List[BaseException] = []

        def worker():
            while True:
                try:
                    s = work.get_nowait()
                except queue.Empty:
                    return
                try:
                    rows[s] = self._fetch(step, s)
                except BaseException as e:   # surfaced to the caller
                    errors.append(e)

        n = min(self.cfg.n_workers, self.local_batch)
        threads = [threading.Thread(target=worker) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        seqs = np.stack(rows)                       # (B_loc, seq+1)
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "targets": seqs[:, 1:].astype(np.int32)}

    # -------------------------------------------------------------- iterate
    def _producer(self) -> None:
        step = self._next_step
        while not self._stop.is_set():
            batch = self.build_batch(step)
            while not self._stop.is_set():
                try:
                    self._queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        self._stop.clear()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()
        try:
            while True:
                step, batch = self._queue.get()
                self._next_step = step + 1
                yield batch
        finally:
            self.stop()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            # drain so the producer can observe the stop flag
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5)
            self._thread = None

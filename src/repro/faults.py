"""Deterministic fault injection for the serve/sim/coordination planes.

LERC was built as a Spark memory manager, where executor loss, dropped
BlockManager messages and lineage recompute of lost blocks are the
operating baseline. This module makes failure a first-class, *seeded*
input to every layer of the reproduction: a ``FaultPlan`` schedules fault
events on the virtual clock (shard/worker crashes at time t) and draws
probabilistic ones (bus message drop/delay/duplication per channel,
disk-tier I/O errors, slow promotions) from one ``numpy`` generator, so a
faulted run is exactly reproducible — on CI CPU as on a TPU pod.

Consumers:

* ``serve.ShardedFrontend`` — shard crashes (failover: re-route, requeue
  in-flight requests with capped exponential backoff, rebuild the replica
  via the anti-entropy ``resync`` protocol);
* ``core.MessageBus`` — per-channel drop/delay/duplication of messages;
* ``serve.TieredKVStore`` / ``serve.DiskBlockPool`` — injected ``OSError``
  on disk-tier reads/writes (quarantine after ``quarantine_after``
  consecutive errors) and slow-promotion stalls with a timeout;
* ``sim.ClusterSim`` — worker crashes (cached blocks lost, lineage
  recompute charged to the makespan).

Determinism contract: the injector draws from its generator ONLY when a
matching fault is configured for that site — adding a fault on one
channel never perturbs the draws (and therefore the outcome) of another.
An **empty plan is bit-identical to no plan at all**: every hook in the
consumers is gated on a predicate that an empty plan never satisfies
(``tests/test_faults.py`` proves tokens, eviction logs and the full
metrics dict unchanged).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, fields
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class BusFault:
    """Probabilistic fault on one bus channel (message ``kind``, or ``"*"``
    for every kind). Checks are ordered drop → duplicate → delay, each an
    independent draw."""

    channel: str = "*"
    drop_p: float = 0.0
    delay_p: float = 0.0
    delay: float = 0.5          # virtual-clock units a delayed message waits
    dup_p: float = 0.0


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic schedule of faults plus recovery tuning.

    ``shard_crashes`` / ``worker_crashes`` are ``(t, index)`` pairs on the
    consumer's virtual clock: the serve frontend kills shard ``index`` the
    first time that shard's clock reaches ``t``; the simulator loses
    worker ``index``'s cached blocks at simulated time ``t`` (the executor
    restarts with an empty cache — Spark's standard recovery).
    """

    seed: int = 0
    shard_crashes: Tuple[Tuple[float, int], ...] = ()
    worker_crashes: Tuple[Tuple[float, int], ...] = ()
    bus_faults: Tuple[BusFault, ...] = ()
    disk_read_error_p: float = 0.0
    disk_write_error_p: float = 0.0
    quarantine_after: int = 3       # consecutive disk I/O errors -> quarantine
    promotion_stall_p: float = 0.0
    promotion_stall: float = 0.0    # virtual-clock stall per slow promotion
    promotion_timeout: float = float("inf")   # stalls past this abandon the
    #                                           promotion (chain recomputes)
    retry_backoff: float = 0.5      # failover re-admission: base backoff
    retry_backoff_cap: float = 4.0  # ... and its exponential cap

    @property
    def empty(self) -> bool:
        """True iff this plan injects nothing (recovery tuning aside)."""
        return not (self.shard_crashes or self.worker_crashes
                    or self.bus_faults
                    or self.disk_read_error_p > 0.0
                    or self.disk_write_error_p > 0.0
                    or self.promotion_stall_p > 0.0)

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)

    def backoff(self, retries: int) -> float:
        """Capped exponential backoff before a failed-over request's
        re-admission (``retries`` >= 1)."""
        return min(self.retry_backoff * (2.0 ** (retries - 1)),
                   self.retry_backoff_cap)

    @classmethod
    def from_json(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            raw = json.load(f)
        known = {f.name for f in fields(cls)}
        unknown = set(raw) - known
        if unknown:
            raise ValueError(f"unknown fault-plan keys {sorted(unknown)}; "
                             f"have {sorted(known)}")
        kw = dict(raw)
        for key in ("shard_crashes", "worker_crashes"):
            if key in kw:
                kw[key] = tuple((float(t), int(i)) for t, i in kw[key])
        if "bus_faults" in kw:
            kw["bus_faults"] = tuple(BusFault(**bf) for bf in kw["bus_faults"])
        if "promotion_timeout" in kw and kw["promotion_timeout"] is None:
            kw["promotion_timeout"] = float("inf")
        return cls(**kw)


class FaultInjector:
    """Runtime companion of a ``FaultPlan``: owns the seeded generator,
    the fired-event bookkeeping and the fault/recovery counters. One
    injector is shared by every layer of a run (bus, stores, frontend) so
    the draw sequence — and therefore the whole faulted execution — is a
    pure function of the plan."""

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.counters: Dict[str, int] = {}
        self._fired: set = set()
        self._bus_by_kind: Dict[str, Tuple[BusFault, ...]] = {}
        for bf in plan.bus_faults:
            self._bus_by_kind.setdefault(bf.channel, ())
            self._bus_by_kind[bf.channel] += (bf,)

    # --------------------------------------------------------------- common
    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def claim(self, key) -> bool:
        """Fire-once bookkeeping for scheduled events: True the first time
        ``key`` is claimed, False after."""
        if key in self._fired:
            return False
        self._fired.add(key)
        return True

    # ------------------------------------------------------------------ bus
    def bus_action(self, kind: str) -> Optional[tuple]:
        """Fault decision for one message of ``kind``: ``None`` (deliver),
        ``("drop",)``, ``("dup",)`` or ``("delay", t)``. Draws happen only
        for kinds a fault is configured on."""
        matching = self._bus_by_kind.get(kind, ())
        if kind != "*":
            matching += self._bus_by_kind.get("*", ())
        for bf in matching:
            if bf.drop_p > 0.0 and self.rng.random() < bf.drop_p:
                return ("drop",)
            if bf.dup_p > 0.0 and self.rng.random() < bf.dup_p:
                return ("dup",)
            if bf.delay_p > 0.0 and self.rng.random() < bf.delay_p:
                return ("delay", bf.delay)
        return None

    # ----------------------------------------------------------------- disk
    def disk_read_fails(self) -> bool:
        p = self.plan.disk_read_error_p
        return p > 0.0 and bool(self.rng.random() < p)

    def disk_write_fails(self) -> bool:
        p = self.plan.disk_write_error_p
        return p > 0.0 and bool(self.rng.random() < p)

    # ------------------------------------------------------------ promotion
    def promotion_stall(self) -> float:
        """Virtual-clock stall this promotion suffers (0.0 = healthy)."""
        p = self.plan.promotion_stall_p
        if p > 0.0 and self.rng.random() < p:
            return self.plan.promotion_stall
        return 0.0

"""codeqwen1.5-7b — qwen1.5 architecture sized for code
[hf:Qwen/CodeQwen1.5-7B]. 32L d_model=4096 32H (kv=32: full MHA KV per the
assignment) d_ff=13440 vocab=92416. QKV bias, SwiGLU, rope theta 1e6.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="codeqwen1_5_7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_head=128,
        d_ff=13440, vocab=92_416,
        qkv_bias=True, act="swiglu", tie_embeddings=False,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="codeqwen1_5_7b_smoke", family="dense",
        n_layers=3, d_model=48, n_heads=3, n_kv_heads=3, d_head=16,
        d_ff=144, vocab=512,
        qkv_bias=True, act="swiglu", tie_embeddings=False,
    )

"""paligemma-3b — SigLIP vision encoder + gemma decoder [arXiv:2407.07726].
18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216. The SigLIP frontend
is a STUB per the assignment: ``input_specs()`` provides 256 precomputed
patch embeddings (dim 1152, SigLIP So400m output), projected and prepended
as a bidirectional prefix (PaliGemma's prefix-LM attention).
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="paligemma_3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_head=256,
        d_ff=16384, vocab=257_216,
        act="geglu", embed_scale=True,
        frontend="patch_embed", frontend_len=256, frontend_dim=1152,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="paligemma_3b_smoke", family="vlm",
        n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, d_head=32,
        d_ff=128, vocab=512,
        act="geglu", embed_scale=True,
        frontend="patch_embed", frontend_len=8, frontend_dim=24,
    )

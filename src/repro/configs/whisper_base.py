"""whisper-base — encoder-decoder ASR backbone [arXiv:2212.04356].
6L encoder + 6L decoder, d_model=512 8H (MHA kv=8) d_ff=2048 vocab=51865.
LayerNorm + GELU (original whisper), learned decoder positions. The conv
audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, 1500, 512) — the output length of
whisper's stride-2 conv stem on 30 s of audio.

Whisper's realistic decoder length is 448; the 32k decode/prefill cells are
exercised for sharding coherence (DESIGN.md §5), sized by max_seq_len.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="whisper_base", family="encdec",
        n_layers=6, n_encoder_layers=6,
        d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
        d_ff=2048, vocab=51_865,
        norm="layernorm", act="gelu", tie_embeddings=True,
        frontend="audio_frames", frontend_len=1500,
        max_seq_len=32_768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="whisper_base_smoke", family="encdec",
        n_layers=2, n_encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=512,
        norm="layernorm", act="gelu", tie_embeddings=True,
        frontend="audio_frames", frontend_len=12,
        max_seq_len=128,
    )

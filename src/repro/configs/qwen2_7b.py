"""qwen2-7b — dense GQA with QKV bias [arXiv:2407.10671].
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. SwiGLU,
rope theta 1e6.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen2_7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_head=128,
        d_ff=18944, vocab=152_064,
        qkv_bias=True, act="swiglu", tie_embeddings=False,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen2_7b_smoke", family="dense",
        n_layers=3, d_model=56, n_heads=7, n_kv_heads=1, d_head=8,
        d_ff=112, vocab=512,
        qkv_bias=True, act="swiglu", tie_embeddings=False,
    )

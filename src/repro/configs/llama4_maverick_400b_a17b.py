"""llama4-maverick-400b-a17b — interleaved dense/MoE with top-1 routing +
shared expert, early-fusion multimodal [hf:meta-llama/Llama-4-Maverick].
48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048;
128 experts top-1 + 1 shared expert on alternating layers ("GM" pattern);
dense layers use d_ff=16384 (hf config intermediate_size of the dense MLP).
Early fusion is out of scope for the LM backbone cells (no image shape in
the assigned set); text-only shapes are exercised.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="llama4_maverick_400b_a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=8192, vocab=202_048,
        layer_pattern="GM", dense_d_ff=16384,
        n_experts=128, top_k=1, n_shared_experts=1,
        act="swiglu", rope_theta=500_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="llama4_maverick_400b_a17b_smoke", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=96, vocab=512,
        layer_pattern="GM", dense_d_ff=192,
        n_experts=8, top_k=1, n_shared_experts=1,
        act="swiglu",
    )

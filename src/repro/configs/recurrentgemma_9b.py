"""recurrentgemma-9b — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427]. 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Pattern "RRL": two Griffin recurrent blocks then one local-attention block
(window 2048); 38 = 12*3 + "RR" tail. Gemma-style: geglu, embed scaling,
head_dim 256.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="recurrentgemma_9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
        d_ff=12288, vocab=256_000,
        layer_pattern="RRL", window=2048, rnn_width=4096, conv_width=4,
        act="geglu", embed_scale=True, rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="recurrentgemma_9b_smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, d_head=32,
        d_ff=128, vocab=512,
        layer_pattern="RRL", window=16, rnn_width=64, conv_width=4,
        act="geglu", embed_scale=True,
    )

"""moonshot-v1-16b-a3b — Moonlight-style fine-grained MoE
[hf:moonshotai/Moonlight-16B-A3B]. 48L d_model=2048 16H (kv=16) d_ff=1408
(per expert) vocab=163840; 64 experts, top-6, +2 shared experts
(DeepSeek-V3-family routing). All layers MoE per the assignment config.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="moonshot_v1_16b_a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab=163_840,
        layer_pattern="M", n_experts=64, top_k=6, n_shared_experts=2,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="moonshot_v1_16b_a3b_smoke", family="moe",
        n_layers=3, d_model=48, n_heads=3, n_kv_heads=3, d_head=16,
        d_ff=32, vocab=512,
        layer_pattern="M", n_experts=8, top_k=2, n_shared_experts=1,
        act="swiglu",
    )

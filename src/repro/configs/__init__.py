"""repro.configs — assigned architectures and benchmark shapes.

Each ``<arch>.py`` exposes ``full()`` (the exact published config) and
``smoke()`` (same family, reduced: few layers, narrow width, tiny vocab) —
smoke configs run a real train/decode step on CPU; full configs are only
ever lowered AOT (dry-run).

``SHAPES`` are the assigned input-shape set; ``cells()`` enumerates the
(arch x shape) grid with the documented skips (DESIGN.md §5):
``long_500k`` needs sub-quadratic decode state, so it runs only for the
hybrid/ssm archs (+ gemma2, whose decode step is O(L) with half the layers
window-bounded).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..models.common import ModelConfig

ARCH_IDS = [
    "recurrentgemma_9b",
    "qwen1_5_110b",
    "codeqwen1_5_7b",
    "gemma2_27b",
    "qwen2_7b",
    "paligemma_3b",
    "moonshot_v1_16b_a3b",
    "llama4_maverick_400b_a17b",
    "whisper_base",
    "rwkv6_3b",
]

# aliases accepted on the CLI (--arch recurrentgemma-9b etc.)
def canonical(arch: str) -> str:
    a = arch.replace("-", "_").replace(".", "_")
    if a not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    return a


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}

# archs whose decode state is sub-quadratic enough for the 500k cell
_LONG_OK = {"recurrentgemma_9b", "rwkv6_3b", "gemma2_27b"}


def get(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __name__)
    return mod.smoke() if smoke else mod.full()


def cells() -> List[Tuple[str, str]]:
    """Every (arch, shape) pair exercised by the dry-run."""
    out: List[Tuple[str, str]] = []
    for a in ARCH_IDS:
        for s in SHAPES:
            if s == "long_500k" and a not in _LONG_OK:
                continue
            out.append((a, s))
    return out


def skipped_cells() -> List[Tuple[str, str, str]]:
    return [(a, "long_500k",
             "pure full attention at 524288: quadratic prefill; skipped per "
             "assignment (DESIGN.md §5)")
            for a in ARCH_IDS if a not in _LONG_OK]

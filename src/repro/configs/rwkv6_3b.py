"""rwkv6-3b — Finch: attention-free, data-dependent per-channel decay
[arXiv:2404.05892]. 32L d_model=2560 d_ff=8960 vocab=65536. Time-mix
(chunked linear attention with LoRA-modulated decay) + channel-mix with
squared-ReLU; 16 heads x 160 head dim.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="rwkv6_3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=16, n_kv_heads=16, d_head=160,
        d_ff=8960, vocab=65_536,
        layer_pattern="W", act="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="rwkv6_3b_smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=160, vocab=512,
        layer_pattern="W", act="gelu",
    )

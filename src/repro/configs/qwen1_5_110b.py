"""qwen1.5-110b — dense GQA transformer with QKV bias
[hf:Qwen/Qwen1.5-110B]. 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064. SwiGLU, untied embeddings, rope theta 1e6.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="qwen1_5_110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=49152, vocab=152_064,
        qkv_bias=True, act="swiglu", tie_embeddings=False,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="qwen1_5_110b_smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=160, vocab=512,
        qkv_bias=True, act="swiglu", tie_embeddings=False,
    )

"""gemma2-27b — local+global alternating attention with logit softcaps
[arXiv:2408.00118]. 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000. Window 4096 on local layers; attn softcap 50, final softcap
30; sandwich (post) norms; geglu; embed scaling; head_dim 128.
"""
from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch="gemma2_27b", family="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
        d_ff=36864, vocab=256_000,
        layer_pattern="LG", window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_norms=True, act="geglu", embed_scale=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch="gemma2_27b_smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=512,
        layer_pattern="LG", window=8,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_norms=True, act="geglu", embed_scale=True,
    )

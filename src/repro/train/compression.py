"""Cross-pod gradient compression with error feedback.

At 2+ pods the data-parallel gradient all-reduce crosses the (slow)
inter-pod links. Int8 compression with error feedback (1-bit-Adam-family,
Seide et al. 2014; Tang et al. arXiv:2102.02888) cuts those bytes 2x vs
bf16 / 4x vs fp32 while error feedback keeps convergence: the residual of
each quantization is carried and added to the next step's gradient, so the
*time-averaged* transmitted gradient is unbiased.

``compress_grads`` applies quantize→dequantize with a carried error buffer
— the optimizer sees exactly what a compressed wire transfer would deliver
(numerics are real). The byte saving enters the roofline's collective term
analytically (EXPERIMENTS.md §Perf): XLA SPMD emits the all-reduce from
shardings, so the wire format itself is not re-implemented here; the
fidelity-relevant part (what the update sees) is.

The quantization math lives in the shared ``repro.quant`` (the serve tier
demotes KV blocks through the same kernels), so train and serve report
byte ratios from one formula.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import quant


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    return quant.quantize_tensor(x, quant.INT8)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return quant.dequantize_tensor(q, scale)


def ef_init(params) -> Any:
    """Error-feedback residual buffers (fp32, one per parameter)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, ef_state):
    """Error-feedback int8 round trip.

    g_corrected = g + e ;  wire = Q(g_corrected) ;  e' = g_corrected - wire
    Returns (wire_grads, new_ef_state).
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _quantize_int8(gf)
        wire = _dequantize(q, s)
        return wire, gf - wire

    pairs = jax.tree.map(one, grads, ef_state)
    wire = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return wire, new_ef


def compression_ratio(dtype=jnp.float32, numel: Optional[int] = None,
                      spec: quant.QuantSpec = quant.INT8) -> float:
    """Wire-byte ratio vs the uncompressed gradient dtype.

    With ``numel`` the ratio is exact for one tensor of that size: it
    charges the f32 scale that rides with every quantized tensor (a
    64-element bf16 tensor compresses 128/(64+4) ≈ 1.88x, not 2x).
    Without ``numel`` it is the asymptotic per-element ratio (scale
    overhead amortized to zero) — what the roofline's collective term
    wants. Either way the source dtype's real width is priced: bf16
    gradients compress 2x into int8, not the 4x the old f32-only formula
    claimed.
    """
    if numel is None:
        return jnp.dtype(dtype).itemsize / spec.itemsize
    return quant.compression_ratio(numel, dtype, spec, n_scales=1)

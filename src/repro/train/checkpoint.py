"""Sharded, atomic, async-capable checkpointing — no orbax dependency.

Layout:  <dir>/step_<N>/
           manifest.json        # written LAST: {step, leaves: {name: meta}}
           <name>.bin           # raw little-endian bytes per leaf

Atomicity: a checkpoint is written into ``step_<N>.tmp-<pid>`` and
``os.rename``d into place only after the manifest lands, so a crash
mid-write never produces a loadable-but-corrupt checkpoint; ``latest()``
ignores directories without a manifest.

Elasticity: leaves are stored by stable tree-path names with shape+dtype
metadata, never by device layout. ``load`` re-lays every leaf out to the
*current* mesh via ``jax.device_put`` with the caller's shardings — a
checkpoint written on a 512-chip mesh restores on 256 chips, 8 chips or a
laptop (tests/test_checkpoint.py proves a cross-topology round trip).

bf16 et al. are serialized as raw bytes + dtype name (ml_dtypes resolves
them on load), sidestepping ``np.save`` pickling.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree, prefix=()) -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], prefix + (str(k),)))
        return out
    return [("/".join(prefix), tree)]


def _unflatten(leaves: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for name, value in leaves.items():
        parts = name.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root


def save(ckpt_dir: str, step: int, state) -> str:
    """Write one checkpoint synchronously; returns its final path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    for name, leaf in _flatten(state):
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", ".") + ".bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(arr.tobytes())
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def load(path: str, shardings=None) -> Tuple[int, Any]:
    """Load a checkpoint; re-layout onto the current mesh if ``shardings``
    (a tree matching the state) is given. Returns (step, state)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    sh_leaves = dict(_flatten(shardings)) if shardings is not None else {}
    leaves: Dict[str, Any] = {}
    for name, meta in manifest["leaves"].items():
        with open(os.path.join(path, meta["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=_np_dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        sh = sh_leaves.get(name)
        leaves[name] = jax.device_put(arr, sh) if sh is not None \
            else jnp.asarray(arr)
    return manifest["step"], _unflatten(leaves)


def latest(ckpt_dir: str) -> Optional[str]:
    """Newest *complete* checkpoint path (manifest present), or None."""
    if not os.path.isdir(ckpt_dir):
        return None
    best, best_step = None, -1
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        full = os.path.join(ckpt_dir, d)
        if m and os.path.exists(os.path.join(full, "manifest.json")):
            s = int(m.group(1))
            if s > best_step:
                best, best_step = full, s
    return best


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` complete checkpoints, remove the rest."""
    steps = []
    for d in os.listdir(ckpt_dir) if os.path.isdir(ckpt_dir) else []:
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append((int(m.group(1)), os.path.join(ckpt_dir, d)))
    for _, path in sorted(steps)[:-keep]:
        shutil.rmtree(path)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training: ``save`` snapshots to host
    memory synchronously (cheap), serializes on a background thread. At most
    one write is in flight; a new save waits for the previous one (bounded
    memory)."""

    def __init__(self, ckpt_dir: str, keep: int = 3) -> None:
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            try:
                save(self.ckpt_dir, step, host_state)
                gc_old(self.ckpt_dir, self.keep)
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

"""Train-step builder: value_and_grad → (optional) microbatch accumulation
→ (optional) cross-pod gradient compression → AdamW.

The same builder serves three consumers:

* smoke tests / examples  — mesh_ctx=local_context(), tiny configs;
* the real trainer        — jit with in/out shardings from the rules;
* the AOT dry-run         — ``abstract_train_state`` builds the
  ShapeDtypeStruct tree (with shardings) that ``.lower()`` consumes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import abstract_params, init_params, loss_fn, model_spec
from ..models.common import ModelConfig
from ..sharding import MeshContext
from .compression import compress_grads, ef_init
from .optimizer import OptConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = field(default_factory=OptConfig)
    microbatches: int = 1           # gradient accumulation steps
    compress_pod_grads: bool = False
    unroll: int = 1                 # layer-scan unroll (roofline extraction)
    mb_unroll: bool = False         # unroll the microbatch scan (roofline)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def make_train_state(cfg: ModelConfig, tc: TrainConfig,
                     rng: Optional[jax.Array] = None) -> Dict[str, Any]:
    rng = rng if rng is not None else jax.random.key(0)
    params = init_params(rng, model_spec(cfg), dtype=cfg.dtype)
    state = {"params": params, "opt": adamw_init(params, tc.opt)}
    if tc.compress_pod_grads:
        state["ef"] = ef_init(params)
    return state


def abstract_train_state(cfg: ModelConfig, tc: TrainConfig,
                         mesh_ctx: MeshContext) -> Dict[str, Any]:
    """ShapeDtypeStruct state tree with shardings attached (AOT dry-run)."""
    spec = model_spec(cfg)
    sharding_fn = (lambda path, s: mesh_ctx.param_sharding(s)) \
        if mesh_ctx.mesh is not None else None
    params = abstract_params(spec, dtype=cfg.dtype, sharding_fn=sharding_fn)
    f32 = abstract_params(spec, dtype=jnp.float32, sharding_fn=sharding_fn)
    mdt = jnp.dtype(tc.opt.moments_dtype)
    mom = f32 if mdt == jnp.float32 else abstract_params(
        spec, dtype=mdt, sharding_fn=sharding_fn)
    state: Dict[str, Any] = {
        "params": params,
        "opt": {"m": mom, "v": mom,
                "step": jax.ShapeDtypeStruct((), jnp.int32,
                                             sharding=mesh_ctx.replicated())},
    }
    if tc.compress_pod_grads:
        state["ef"] = f32
    return state


# ---------------------------------------------------------------------------
# Step
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, tc: TrainConfig,
                     mesh_ctx: Optional[MeshContext] = None):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (un-jitted;
    callers jit with the shardings they want)."""

    # Gradients (and the fp32 accumulation carry) must be pinned to the
    # parameter shardings: without constraints XLA's propagation pass is
    # free to all-gather a full fp32 copy of each layer's weights inside
    # the optimizer (observed: +18 GiB/device on qwen1.5-110b).
    spec_tree = model_spec(cfg)

    def single_loss(params, mb):
        # constrain at entry: the transpose pins the param COTANGENTS to
        # the same sharded layout right at the scan boundary, so the
        # scan-bwd grad accumulator is allocated sharded, not gathered
        if mesh_ctx is not None and mesh_ctx.mesh is not None:
            params = mesh_ctx.constrain_tree(params, spec_tree)
        return loss_fn(cfg, params, mb, mesh_ctx=mesh_ctx, unroll=tc.unroll)

    def constrain_like_params(tree):
        if mesh_ctx is None or mesh_ctx.mesh is None:
            return tree
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(
                t, mesh_ctx.param_sharding(s)),
            tree, spec_tree)

    def compute_grads(params, batch):
        k = tc.microbatches
        if k <= 1:
            loss, grads = jax.value_and_grad(single_loss)(params, batch)
            return loss, constrain_like_params(grads)
        mbs = jax.tree.map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

        def mb_step(acc, mb):
            loss_acc, gacc = acc
            l, g = jax.value_and_grad(single_loss)(params, mb)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gacc, g)
            return (loss_acc + l, constrain_like_params(gacc)), None

        zeros = constrain_like_params(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, gsum), _ = jax.lax.scan(mb_step, (jnp.zeros(()), zeros),
                                           mbs,
                                           unroll=k if tc.mb_unroll else 1)
        return loss_sum / k, jax.tree.map(lambda g: g / k, gsum)

    def train_step(state, batch):
        loss, grads = compute_grads(state["params"], batch)
        new_state = dict(state)
        if tc.compress_pod_grads:
            grads, new_state["ef"] = compress_grads(grads, state["ef"])
        params, opt, stats = adamw_update(tc.opt, state["params"], grads,
                                          state["opt"])
        new_state["params"] = params
        new_state["opt"] = opt
        return new_state, {"loss": loss, **stats}

    return train_step


def state_shardings(abstract_state):
    """Pull the sharding tree out of an abstract state (for jit)."""
    return jax.tree.map(lambda s: s.sharding, abstract_state)

"""AdamW with fp32 moments over bf16 parameters, global-norm clipping and
warmup-cosine/linear schedules. Pure tree-map math (no optax dependency).

Memory layout (per parameter): bf16 weight + fp32 m + fp32 v = 10 bytes —
the layout the dry-run memory analysis accounts for. The fp32 update is
computed on the fly and cast back to bf16 (stochastic rounding is not
available on CPU; on TPU the cast uses round-to-nearest-even).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # cosine | linear | constant
    min_lr_frac: float = 0.1
    # moment storage dtype: fp32 (default) or bf16 ("memory-efficient
    # AdamW", halves optimizer state — the update math stays fp32). At
    # 400B params on 256 chips the fp32 moments alone are 12.5 GB/chip;
    # bf16 moments are what makes the llama4 train cell fit (§Perf it. 3)
    moments_dtype: str = "float32"  # float32 | bfloat16


def schedule_lr(oc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    if oc.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip((step - oc.warmup_steps)
                     / jnp.maximum(oc.total_steps - oc.warmup_steps, 1),
                     0.0, 1.0)
        if oc.schedule == "cosine":
            decay = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        else:
            decay = 1.0 - (1 - oc.min_lr_frac) * t
    return oc.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def adamw_init(params, oc: Optional[OptConfig] = None) -> Dict[str, Any]:
    mdt = jnp.dtype((oc.moments_dtype if oc else "float32"))
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(oc: OptConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    lr = schedule_lr(oc, step)
    b1, b2 = oc.beta1, oc.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(oc.moments_dtype)

    def upd(p, g, m, v):
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + oc.weight_decay * pf)
        return pf.astype(p.dtype), m.astype(mdt), v.astype(mdt)

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}

"""repro.train — optimizer, train-step builder, checkpointing, gradient
compression (error feedback)."""
from .checkpoint import AsyncCheckpointer, gc_old, latest, load, save
from .compression import compress_grads, ef_init
from .optimizer import (OptConfig, adamw_init, adamw_update,
                        clip_by_global_norm, global_norm, schedule_lr)
from .step import (TrainConfig, abstract_train_state, build_train_step,
                   make_train_state, state_shardings)

__all__ = [
    "AsyncCheckpointer", "gc_old", "latest", "load", "save",
    "compress_grads", "ef_init", "OptConfig", "adamw_init", "adamw_update",
    "clip_by_global_norm", "global_norm", "schedule_lr", "TrainConfig",
    "abstract_train_state", "build_train_step", "make_train_state",
    "state_shardings",
]

"""Serving entry point: continuous batching + LERC prefix cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 12 --policy lerc

With ``--arrival`` the run goes through the always-on front door instead
of the batch loop: requests arrive on a timed trace (Poisson / bursty /
diurnal, seeded), the chosen ``--scheduler`` divides each step's prefill
work against decode latency, per-request TTFT deadlines come from
``--deadline-ms``, and the report adds TTFT/TPOT percentiles and
goodput-under-deadline on the deterministic virtual clock:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --scheduler budgeted --prefill-budget 16 --arrival poisson \
      --arrival-rate 2.0 --deadline-ms 8 --max-queue 64
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import numpy as np

from .. import configs
from ..core import POLICIES
from ..faults import FaultPlan
from ..models import init_params, model_spec
from ..obs import TraceRecorder, jsonable
from ..serve import (BudgetedScheduler, PrefixStore, ServeEngine,
                     ShardedFrontend, TieredKVStore, TracedRequest,
                     latency_stats, play_trace)
from ..sim import bursty_arrivals, diurnal_arrivals, poisson_arrivals

_ARRIVALS = {"poisson": poisson_arrivals, "bursty": bursty_arrivals,
             "diurnal": diurnal_arrivals}


def serve_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    # belady needs a future-access trace the serve path cannot provide
    ap.add_argument("--policy", default="lerc",
                    choices=sorted(p for p in POLICIES if p != "belady"))
    ap.add_argument("--cache-kb", type=int, default=512)
    ap.add_argument("--block-tokens", type=int, default=8)
    ap.add_argument("--shared-prefix", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens per slot per engine step")
    ap.add_argument("--paged-attention", dest="paged", action="store_true",
                    default=None,
                    help="decode straight out of the KV pool via per-slot "
                         "block tables: hits are host-side table writes, "
                         "publish transfers row ownership, no per-slot "
                         "contiguous KV cache (default: on for uniform "
                         "global-attention patterns)")
    ap.add_argument("--no-paged-attention", dest="paged",
                    action="store_false",
                    help="force the PR 2 gather/scatter data plane")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="device KV pool size in blocks "
                         "(default: sized to --cache-kb)")
    ap.add_argument("--host-cache-kb", type=int, default=0,
                    help="host-memory KV tier per engine: device-pressure "
                         "evictions demote blocks here and prefix hits "
                         "promote them back instead of recomputing "
                         "(0 disables the tier; split across --shards)")
    ap.add_argument("--kv-quant", default="none",
                    choices=["none", "int8", "fp8"],
                    help="transcode demoted KV blocks to this format "
                         "(per-layer-per-block f32 scales): the host/disk "
                         "byte budgets then hold ~2-4x more blocks; "
                         "promotion dequantizes on device. 'none' keeps "
                         "every path bit-identical to the lossless tier")
    ap.add_argument("--disk-cache-mb", type=int, default=0,
                    help="disk KV tier per engine (np.memmap row files): "
                         "host-tier evictions demote here instead of "
                         "dying, and lookups promote disk-resident chains "
                         "back to the device pool (0 disables; needs "
                         "--host-cache-kb > 0; split across --shards)")
    ap.add_argument("--disk-dir", default=None,
                    help="directory for the disk tier's memmap files "
                         "(default: a TemporaryDirectory per engine)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor parallelism: shard every KV pool leaf "
                         "(and the paged attention reading it) over a "
                         "1-D model mesh of N devices; block tables and "
                         "the whole store stay host-global. Paged plane "
                         "only. CPU recipe: XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N")
    ap.add_argument("--shards", type=int, default=1,
                    help="cache shards: >1 runs a ShardedFrontend of "
                         "independent engines on the coordination plane, "
                         "splitting --cache-kb across shards")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "decode-first", "budgeted"],
                    help="step scheduler: fcfs (full-chunk prefill for "
                         "every slot), decode-first (prefill only on "
                         "decode-idle steps), budgeted (earliest-deadline-"
                         "first prefill under --prefill-budget)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prompt tokens per step for the budgeted "
                         "scheduler (None = uncapped, 0 = decode-first)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request TTFT deadline on the virtual clock "
                         "(None = best-effort; goodput counts completions)")
    ap.add_argument("--arrival", default=None,
                    choices=sorted(_ARRIVALS),
                    help="drive requests through the timed front door "
                         "with this arrival process instead of the "
                         "batch submit-then-run loop")
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean arrivals per virtual time unit")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission-control queue bound (per shard); "
                         "arrivals past it are shed with QueueFull")
    ap.add_argument("--retry-rejected", type=int, default=0,
                    help="re-submit QueueFull-shed arrivals up to N times, "
                         "waiting the engine's advertised retry-after "
                         "between attempts (retries count against goodput)")
    ap.add_argument("--fault-plan", default=None, metavar="PATH",
                    help="JSON repro.faults.FaultPlan: seeded shard "
                         "crashes, bus drop/delay/dup, disk I/O errors, "
                         "slow promotions — the run then exercises "
                         "failover, quarantine and resync deterministically")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="override the fault plan's seed (same plan, "
                         "different draw sequence)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a Chrome/Perfetto trace of the whole run "
                         "(engine step phases, scheduler decisions, "
                         "request lifecycles, store tier moves, bus "
                         "messages) and write trace-event JSON here; "
                         "render reports with benchmarks/trace_report.py")
    ap.add_argument("--trace-limit", type=int, default=200_000,
                    help="trace ring-buffer size in events (oldest drop)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the final metrics dict (plus the run args) "
                         "as JSON")
    args = ap.parse_args(argv)

    # flag cross-validation up front — a bad combination must die with an
    # actionable message before any model weights are initialised, not
    # half-way through a run (or worse, be silently "repaired")
    if args.disk_cache_mb > 0 and args.host_cache_kb <= 0:
        ap.error("--disk-cache-mb needs --host-cache-kb > 0: blocks demote "
                 "device -> host -> disk, so a disk tier without a host "
                 "tier is unreachable. Add --host-cache-kb.")
    if args.disk_dir is not None and args.disk_cache_mb <= 0:
        ap.error("--disk-dir has no effect without --disk-cache-mb > 0 "
                 "(there is no disk tier to place there)")
    if args.kv_quant != "none" and args.host_cache_kb <= 0:
        ap.error(f"--kv-quant {args.kv_quant} transcodes blocks demoted to "
                 "the host/disk tiers, which --host-cache-kb 0 disables. "
                 "Add --host-cache-kb or drop --kv-quant.")
    if args.prefill_budget is not None and args.scheduler != "budgeted":
        ap.error(f"--prefill-budget only applies to --scheduler budgeted "
                 f"(got --scheduler {args.scheduler})")
    if args.tp > 1 and args.paged is False:
        ap.error("--tp > 1 shards the paged KV pool; it cannot run on the "
                 "gather plane forced by --no-paged-attention")
    if args.fault_seed is not None and args.fault_plan is None:
        ap.error("--fault-seed overrides a plan's seed; pass --fault-plan")
    injector = None
    if args.fault_plan is not None:
        try:
            plan = FaultPlan.from_json(args.fault_plan)
        except (OSError, ValueError, TypeError) as e:
            ap.error(f"--fault-plan {args.fault_plan}: {e}")
        if args.fault_seed is not None:
            plan = dataclasses.replace(plan, seed=args.fault_seed)
        for _, k in plan.shard_crashes:
            if not 0 <= k < args.shards:
                ap.error(f"fault plan crashes shard {k} but --shards is "
                         f"{args.shards} (valid: 0..{args.shards - 1})")
        injector = plan.injector()

    cfg = configs.get(args.arch, smoke=args.smoke)
    params = init_params(jax.random.key(args.seed), model_spec(cfg),
                         dtype=cfg.dtype)
    host_bytes = args.host_cache_kb * 1024
    disk_bytes = args.disk_cache_mb * 1024 * 1024
    absolute_kv = set(cfg.layer_pattern) <= {"G", "M"}
    if args.paged is None:
        # zero-copy paged attention is the default wherever the KV layout
        # supports it (absolute positions); the engine itself falls back
        # to the gather plane — with a warning — if asked for more
        args.paged = absolute_kv
    if args.prefill_chunk > 1 and not absolute_kv:
        print(f"warning: pattern {cfg.layer_pattern!r} has rolling/"
              "recurrent layers; clamping --prefill-chunk to 1",
              file=sys.stderr)
        args.prefill_chunk = 1
    # schedulers are stateless policy objects — one instance is safely
    # shared by every shard
    scheduler = (BudgetedScheduler(args.prefill_budget)
                 if args.scheduler == "budgeted" else args.scheduler)
    if args.shards > 1:
        eng = ShardedFrontend(
            cfg, params, args.shards, max_slots=args.slots,
            max_seq=args.max_seq,
            capacity_bytes=max(args.cache_kb * 1024 // args.shards, 1),
            policy=args.policy, block_tokens=args.block_tokens,
            prefill_chunk=args.prefill_chunk, pool_blocks=args.pool_blocks,
            host_capacity_bytes=host_bytes // args.shards,
            kv_quant=args.kv_quant,
            disk_capacity_bytes=disk_bytes // args.shards,
            disk_dir=args.disk_dir,
            paged=args.paged, scheduler=scheduler,
            max_queue=args.max_queue, tp=args.tp, faults=injector)
    else:
        if host_bytes > 0:
            store: PrefixStore = TieredKVStore(
                capacity_bytes=args.cache_kb * 1024, policy=args.policy,
                block_tokens=args.block_tokens,
                host_capacity_bytes=host_bytes,
                kv_quant=args.kv_quant,
                disk_capacity_bytes=disk_bytes,
                disk_dir=args.disk_dir)
            # disk-error / slow-promotion injection: attach before the
            # engine wires the pools so the disk pool inherits the injector
            store.faults = injector
        else:
            store = PrefixStore(capacity_bytes=args.cache_kb * 1024,
                                policy=args.policy,
                                block_tokens=args.block_tokens)
        eng = ServeEngine(cfg, params, max_slots=args.slots,
                          max_seq=args.max_seq, store=store,
                          prefill_chunk=args.prefill_chunk,
                          pool_blocks=args.pool_blocks, paged=args.paged,
                          scheduler=scheduler, max_queue=args.max_queue,
                          tp=args.tp)

    recorder = None
    if args.trace is not None:
        recorder = TraceRecorder(limit=args.trace_limit)
        eng.attach_trace(recorder)

    if host_bytes > 0:
        # a host budget below one KV block (per shard) sizes the pool to
        # zero rows, silently disabling the tier — say so up front
        engines = eng.shards if args.shards > 1 else [eng]
        if any(getattr(e.store, "host_pool", None) is None
               or e.store.host_pool.num_blocks == 0 for e in engines):
            print(f"warning: --host-cache-kb {args.host_cache_kb} is below "
                  f"one KV block per {'shard' if args.shards > 1 else 'engine'}"
                  f" ({engines[0].pool.block_nbytes} B); host tier disabled",
                  file=sys.stderr)

    rng = np.random.default_rng(args.seed)
    n_families = max(args.requests // 4, 1)
    prefixes = [list(rng.integers(0, cfg.vocab, args.shared_prefix))
                for _ in range(n_families)]
    prompts = [prefixes[i % n_families]
               + list(rng.integers(0, cfg.vocab, 8))
               for i in range(args.requests)]
    t0 = time.time()
    report = None
    if args.arrival is not None:
        times = _ARRIVALS[args.arrival](args.requests, args.arrival_rate,
                                        args.seed)
        trace = [TracedRequest(t=t, prompt=p, max_new=args.max_new,
                               deadline=args.deadline_ms)
                 for t, p in zip(times, prompts)]
        report = play_trace(eng, trace, retry_rejected=args.retry_rejected)
    else:
        for p in prompts:
            eng.submit(p, max_new=args.max_new)
        eng.run()
    if args.shards > 1:
        if injector is not None:
            # lossy status traffic leaves replicas behind by design; the
            # anti-entropy resync is the documented repair before verify
            eng.resync_replicas()
        eng.verify_replicas()       # smoke doubles as a coherence proof
    m = eng.metrics()
    if report is not None:
        m.update(latency_stats(report))
    if injector is not None:
        for name in sorted(injector.counters):
            m[name] = injector.counters[name]
    paged_on = (all(e.paged for e in eng.shards) if args.shards > 1
                else eng.paged)
    print(f"policy={args.policy}  shards={args.shards}  tp={args.tp}  "
          f"paged={'on' if paged_on else 'off'}  "
          f"scheduler={args.scheduler}"
          + (f"  arrival={args.arrival}@{args.arrival_rate}"
             if args.arrival else "")
          + f"  host_cache_kb={args.host_cache_kb}  "
          f"kv_quant={args.kv_quant}  disk_cache_mb={args.disk_cache_mb}  "
          f"wall={time.time()-t0:.1f}s")
    for k, v in m.items():
        print(f"  {k:26s} {v:.3f}" if isinstance(v, float)
              else f"  {k:26s} {v}")
    if recorder is not None:
        recorder.export(args.trace)
        print(f"trace: {args.trace}  events={len(recorder.events)}"
              f"  emitted={recorder.n_emitted}"
              f"  dropped={recorder.n_dropped}")
    if args.metrics_json is not None:
        with open(args.metrics_json, "w") as f:
            json.dump(jsonable({"args": vars(args), "metrics": m}),
                      f, indent=2)
        print(f"metrics: {args.metrics_json}")
    close = getattr(eng, "close", None)
    if close is not None:
        close()       # deterministic disk-tier teardown (memmaps + files)
    return 0


if __name__ == "__main__":
    sys.exit(serve_main())

"""Serving entry point: continuous batching + LERC prefix cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 12 --policy lerc
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from .. import configs
from ..core import POLICIES
from ..models import init_params, model_spec
from ..serve import PrefixStore, ServeEngine, ShardedFrontend, TieredKVStore


def serve_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    # belady needs a future-access trace the serve path cannot provide
    ap.add_argument("--policy", default="lerc",
                    choices=sorted(p for p in POLICIES if p != "belady"))
    ap.add_argument("--cache-kb", type=int, default=512)
    ap.add_argument("--block-tokens", type=int, default=8)
    ap.add_argument("--shared-prefix", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens per slot per engine step")
    ap.add_argument("--paged-attention", dest="paged", action="store_true",
                    default=None,
                    help="decode straight out of the KV pool via per-slot "
                         "block tables: hits are host-side table writes, "
                         "publish transfers row ownership, no per-slot "
                         "contiguous KV cache (default: on for uniform "
                         "global-attention patterns)")
    ap.add_argument("--no-paged-attention", dest="paged",
                    action="store_false",
                    help="force the PR 2 gather/scatter data plane")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="device KV pool size in blocks "
                         "(default: sized to --cache-kb)")
    ap.add_argument("--host-cache-kb", type=int, default=0,
                    help="host-memory KV tier per engine: device-pressure "
                         "evictions demote blocks here and prefix hits "
                         "promote them back instead of recomputing "
                         "(0 disables the tier; split across --shards)")
    ap.add_argument("--shards", type=int, default=1,
                    help="cache shards: >1 runs a ShardedFrontend of "
                         "independent engines on the coordination plane, "
                         "splitting --cache-kb across shards")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    params = init_params(jax.random.key(args.seed), model_spec(cfg),
                         dtype=cfg.dtype)
    host_bytes = args.host_cache_kb * 1024
    absolute_kv = set(cfg.layer_pattern) <= {"G", "M"}
    if args.paged is None:
        # zero-copy paged attention is the default wherever the KV layout
        # supports it (absolute positions); the engine itself falls back
        # to the gather plane — with a warning — if asked for more
        args.paged = absolute_kv
    if args.prefill_chunk > 1 and not absolute_kv:
        print(f"warning: pattern {cfg.layer_pattern!r} has rolling/"
              "recurrent layers; clamping --prefill-chunk to 1",
              file=sys.stderr)
        args.prefill_chunk = 1
    if args.shards > 1:
        eng = ShardedFrontend(
            cfg, params, args.shards, max_slots=args.slots,
            max_seq=args.max_seq,
            capacity_bytes=max(args.cache_kb * 1024 // args.shards, 1),
            policy=args.policy, block_tokens=args.block_tokens,
            prefill_chunk=args.prefill_chunk, pool_blocks=args.pool_blocks,
            host_capacity_bytes=host_bytes // args.shards,
            paged=args.paged)
    else:
        if host_bytes > 0:
            store: PrefixStore = TieredKVStore(
                capacity_bytes=args.cache_kb * 1024, policy=args.policy,
                block_tokens=args.block_tokens,
                host_capacity_bytes=host_bytes)
        else:
            store = PrefixStore(capacity_bytes=args.cache_kb * 1024,
                                policy=args.policy,
                                block_tokens=args.block_tokens)
        eng = ServeEngine(cfg, params, max_slots=args.slots,
                          max_seq=args.max_seq, store=store,
                          prefill_chunk=args.prefill_chunk,
                          pool_blocks=args.pool_blocks, paged=args.paged)

    if host_bytes > 0:
        # a host budget below one KV block (per shard) sizes the pool to
        # zero rows, silently disabling the tier — say so up front
        engines = eng.shards if args.shards > 1 else [eng]
        if any(getattr(e.store, "host_pool", None) is None
               or e.store.host_pool.num_blocks == 0 for e in engines):
            print(f"warning: --host-cache-kb {args.host_cache_kb} is below "
                  f"one KV block per {'shard' if args.shards > 1 else 'engine'}"
                  f" ({engines[0].pool.block_nbytes} B); host tier disabled",
                  file=sys.stderr)

    rng = np.random.default_rng(args.seed)
    n_families = max(args.requests // 4, 1)
    prefixes = [list(rng.integers(0, cfg.vocab, args.shared_prefix))
                for _ in range(n_families)]
    t0 = time.time()
    for i in range(args.requests):
        pfx = prefixes[i % n_families]
        sfx = list(rng.integers(0, cfg.vocab, 8))
        eng.submit(pfx + sfx, max_new=args.max_new)
    eng.run()
    if args.shards > 1:
        eng.verify_replicas()       # smoke doubles as a coherence proof
    m = eng.metrics()
    paged_on = (all(e.paged for e in eng.shards) if args.shards > 1
                else eng.paged)
    print(f"policy={args.policy}  shards={args.shards}  "
          f"paged={'on' if paged_on else 'off'}  "
          f"host_cache_kb={args.host_cache_kb}  wall={time.time()-t0:.1f}s")
    for k, v in m.items():
        print(f"  {k:26s} {v:.3f}" if isinstance(v, float)
              else f"  {k:26s} {v}")
    return 0


if __name__ == "__main__":
    sys.exit(serve_main())

"""Mesh construction for the production fleet.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — critical because the dry-run
launches with 512 forced host devices while tests/benches must see 1.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..sharding import KVShardCtx, MeshContext, serve_tp_context


def make_serve_tp_context(tp: int) -> KVShardCtx:
    """Serve-plane TP mesh (PR 7): 1-D ``model`` axis over the first
    ``tp`` local devices, sharding the paged KV pool's head dimension.
    CPU-testable with XLA_FLAGS=--xla_force_host_platform_device_count=N
    exactly like ``make_debug_mesh_context``."""
    return serve_tp_context(tp)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods,
    512 chips as (pod=2, data=16, model=16) — the ``pod`` axis carries
    cross-pod data parallelism (slow links: DCN/ICI-oversubscribed)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_context(*, multi_pod: bool = False,
                      seq_shard: bool = True,
                      fsdp_params: bool = True) -> MeshContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return MeshContext(mesh=mesh, data_axes=data_axes, model_axis="model",
                       seq_shard=seq_shard, fsdp_params=fsdp_params)


def make_debug_mesh_context(shape: Tuple[int, ...] = (2, 2),
                            axes: Tuple[str, ...] = ("data", "model"),
                            **kw) -> MeshContext:
    """Tiny mesh over however many (forced) host devices exist — used by
    sharding unit tests with XLA_FLAGS=--xla_force_host_platform_device_count=4."""
    mesh = jax.make_mesh(shape, axes)
    data_axes = tuple(a for a in axes if a != "model")
    return MeshContext(mesh=mesh, data_axes=data_axes, model_axis="model",
                       **kw)

"""Step functions + abstract input specs for every (arch × shape) cell.

``build_cell(arch, shape, mesh_ctx)`` returns ``(fn, args, out_shardings)``
ready for ``jax.jit(fn, out_shardings=...).lower(*args)``:

* ``train``   — full train step (fwd + bwd + AdamW) on ShapeDtypeStructs of
                the sharded train state and token batch;
* ``prefill`` — forward over the full sequence, returning only the
                last-position logits (what a serving engine samples from);
* ``decode``  — one ``serve_step``: a single new token against a KV cache
                of ``seq_len``, returning (greedy token, updated cache).

Everything is ShapeDtypeStruct — no allocation ever happens here.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import configs
from ..models import (abstract_params, batch_shapes, decode_cache_shapes,
                      decode_step, forward, model_spec)
from ..models.api import cache_leaf_dtype
from ..models.common import ModelConfig
from ..sharding import MeshContext
from ..train import (TrainConfig, abstract_train_state, build_train_step,
                     state_shardings)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def abstract_batch(cfg: ModelConfig, mesh_ctx: MeshContext,
                   global_batch: int, seq_len: int) -> Dict:
    out = {}
    for name, (shape, dtype) in batch_shapes(cfg, global_batch,
                                             seq_len).items():
        out[name] = mesh_ctx.batch_sharding(shape, dtype)
    return out


def abstract_cache(cfg: ModelConfig, mesh_ctx: MeshContext, batch: int,
                   max_seq: int, enc_len: int = 0):
    shapes = decode_cache_shapes(cfg, batch, max_seq, enc_len)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        name = path[-1] if path else ""
        return mesh_ctx.cache_sharding(path, tree,
                                       cache_leaf_dtype(cfg, name))

    return walk(shapes)


def cache_shardings_tree(abstract):
    return jax.tree.map(lambda s: s.sharding, abstract)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, mesh_ctx: Optional[MeshContext],
                       unroll: int = 1):
    def prefill_step(params, batch):
        logits = forward(cfg, params, batch, mesh_ctx=mesh_ctx,
                         unroll=unroll, last_logit_only=True)
        return logits[:, -1, :]        # (B, vocab): next-token distribution
    return prefill_step


def build_serve_step(cfg: ModelConfig, mesh_ctx: Optional[MeshContext],
                     unroll: int = 1):
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_step(cfg, params, cache, tokens, pos,
                                        mesh_ctx=mesh_ctx, unroll=unroll)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache
    return serve_step


# ---------------------------------------------------------------------------
# Cells
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, mesh_ctx: MeshContext, *,
               train_cfg: Optional[TrainConfig] = None,
               cfg_override: Optional[ModelConfig] = None,
               unroll: int = 1):
    """(fn, args, out_shardings) for one dry-run cell."""
    cfg = cfg_override if cfg_override is not None else configs.get(arch)
    shape = configs.SHAPES[shape_name]
    tc = train_cfg or TrainConfig(unroll=unroll)

    if shape.kind == "train":
        state = abstract_train_state(cfg, tc, mesh_ctx)
        batch = abstract_batch(cfg, mesh_ctx, shape.global_batch,
                               shape.seq_len)
        fn = build_train_step(cfg, tc, mesh_ctx)
        out_sh = (state_shardings(state),
                  {"loss": mesh_ctx.replicated(),
                   "grad_norm": mesh_ctx.replicated(),
                   "lr": mesh_ctx.replicated()})
        return fn, (state, batch), out_sh

    sharding_fn = (lambda path, s: mesh_ctx.param_sharding(s)) \
        if mesh_ctx.mesh is not None else None
    params = abstract_params(model_spec(cfg), dtype=cfg.dtype,
                             sharding_fn=sharding_fn)

    if shape.kind == "prefill":
        batch = abstract_batch(cfg, mesh_ctx, shape.global_batch,
                               shape.seq_len)
        batch.pop("targets")
        fn = build_prefill_step(cfg, mesh_ctx, unroll=unroll)
        # (B, vocab) — batch over data axes, vocab over model
        out_sh = mesh_ctx.batch_sharding(
            (shape.global_batch, cfg.vocab), cfg.dtype).sharding
        return fn, (params, batch), out_sh

    if shape.kind == "decode":
        B = shape.global_batch
        cache = abstract_cache(cfg, mesh_ctx, B, shape.seq_len,
                               enc_len=cfg.frontend_len)
        tokens = mesh_ctx.batch_sharding((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=mesh_ctx.replicated())
        fn = build_serve_step(cfg, mesh_ctx, unroll=unroll)
        out_sh = (mesh_ctx.batch_sharding((B, 1), jnp.int32).sharding,
                  cache_shardings_tree(cache))
        return fn, (params, cache, tokens, pos), out_sh

    raise ValueError(shape.kind)

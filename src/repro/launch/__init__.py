"""repro.launch — mesh construction, AOT dry-run, trainer and server
entry points. NOTE: ``dryrun`` must be imported first in its process (it
sets XLA_FLAGS before jax initializes devices)."""
from .mesh import make_debug_mesh_context, make_mesh_context, make_production_mesh

__all__ = ["make_debug_mesh_context", "make_mesh_context",
           "make_production_mesh"]

"""Post-SPMD HLO analysis: collective wire bytes + cost/memory extraction.

Shapes in partitioned HLO are per-device shard shapes, so every byte count
below is per-device. Wire cost per collective (ring schedules, n = replica
group size):

* all-gather:          out − in        (bytes received per device)
* reduce-scatter:      in − out
* all-reduce:          2 · out · (n−1)/n   (reduce-scatter + all-gather)
* all-to-all:          out · (n−1)/n
* collective-permute:  out             (one hop)

``lax.scan`` bodies appear once in HLO regardless of trip count (XLA while
loops); the roofline extractor (benchmarks/roofline.py) recovers per-layer
costs by a two-point fit over reduced-depth compiles — this module only
reports what is literally in the artifact.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)(.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every `dtype[dims]` occurrence in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:                                   # [n_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclass
class CollectiveStats:
    per_kind_bytes: Dict[str, float] = field(default_factory=dict)
    per_kind_count: Dict[str, int] = field(default_factory=dict)
    ops: List[Tuple[str, float, int]] = field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return sum(self.per_kind_bytes.values())

    def as_dict(self) -> Dict:
        return {"total_bytes": self.total_bytes,
                "per_kind_bytes": dict(self.per_kind_bytes),
                "per_kind_count": dict(self.per_kind_count)}


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-device wire bytes of every collective in partitioned HLO."""
    stats = CollectiveStats()
    seen_started: set = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _OP_RE.match(line)
        if not m:
            continue
        out_shape, kind, operands, rest = m.groups()
        out_b = _shape_bytes(out_shape)
        in_b = _shape_bytes(operands)
        n = _group_size(line)
        if kind == "all-gather":
            wire = max(out_b - in_b, 0)
        elif kind == "reduce-scatter":
            wire = max(in_b - out_b, 0)
        elif kind == "all-reduce":
            wire = 2.0 * out_b * (n - 1) / max(n, 1)
        elif kind == "all-to-all":
            wire = out_b * (n - 1) / max(n, 1)
        else:                                # collective-permute
            wire = float(out_b)
        stats.per_kind_bytes[kind] = stats.per_kind_bytes.get(kind, 0.0) + wire
        stats.per_kind_count[kind] = stats.per_kind_count.get(kind, 0) + 1
        stats.ops.append((kind, wire, n))
    return stats


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_bytes": float(ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes
                            - ma.alias_size_in_bytes),
    }

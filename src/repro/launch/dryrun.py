import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: AOT lower + compile every (arch × shape) cell on the
production meshes — 256-chip single-pod (data=16, model=16) and 512-chip
multi-pod (pod=2, data=16, model=16) — and dump memory/cost/collective
analysis. No arrays are ever allocated (ShapeDtypeStruct only); the 512
forced host devices exist purely so ``jax.make_mesh`` can build the mesh.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --all --json out.json
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from .. import configs
from ..train import TrainConfig
from .hlo_analysis import collective_stats, cost_summary, memory_summary
from .mesh import make_mesh_context
from .specs import build_cell

V5E_HBM_BYTES = 16 * 2 ** 30          # per-chip HBM, TPU v5e

# per-arch production training recipe (the §Perf hillclimb outcomes):
# llama4-400B needs bf16 optimizer moments (fp32 m+v alone are 12.5 GB/chip
# at 256 chips); everything else keeps fp32 moments.
PROD_OVERRIDES = {
    "llama4_maverick_400b_a17b": {"moments_dtype": "bfloat16"},
}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             unroll: int = 1, cfg_override=None, seq_shard: bool = True,
             microbatches: int = 1, with_collectives: bool = True,
             exact_causal: Optional[bool] = None,
             moments_dtype: str = "float32",
             mb_unroll: bool = False) -> Dict:
    t0 = time.time()
    from ..train import OptConfig
    mesh_ctx = make_mesh_context(multi_pod=multi_pod, seq_shard=seq_shard)
    cfg = cfg_override if cfg_override is not None else configs.get(arch)
    if exact_causal is not None:
        cfg = cfg.replace(exact_causal=exact_causal)
    tc = TrainConfig(opt=OptConfig(moments_dtype=moments_dtype),
                     unroll=unroll, microbatches=microbatches,
                     mb_unroll=mb_unroll)
    fn, args, out_sh = build_cell(arch, shape_name, mesh_ctx,
                                  train_cfg=tc, cfg_override=cfg,
                                  unroll=unroll)
    shape_kind = configs.SHAPES[shape_name].kind
    # production aliasing: the train state / decode cache is donated —
    # without it both the old and new state are live across the step
    donate = (0,) if shape_kind == "train" else \
             (1,) if shape_kind == "decode" else ()
    with mesh_ctx.mesh:
        lowered = jax.jit(fn, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        mem = memory_summary(compiled)
        cost = cost_summary(compiled)
        coll = (collective_stats(compiled.as_text()).as_dict()
                if with_collectives else {})
    n_dev = mesh_ctx.mesh.size
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": f"{'2x16x16' if multi_pod else '16x16'}",
        "devices": n_dev,
        "ok": True,
        "memory": mem,
        "hbm_frac": mem["peak_bytes"] / V5E_HBM_BYTES,
        "cost": cost,
        "collectives": coll,
        "compile_s": round(time.time() - t0, 1),
    }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="architecture id (see repro.configs)")
    ap.add_argument("--shape", choices=list(configs.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every cell on both meshes")
    ap.add_argument("--single-mesh", action="store_true",
                    help="with --all: only the mesh selected by --multi-pod")
    ap.add_argument("--unroll", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=8,
                    help="grad-accumulation steps for train cells "
                         "(production default 8; memory/compute trade)")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--json", help="write results to this file")
    args = ap.parse_args(argv)

    if args.all:
        cells = [(a, s, mp) for (a, s) in configs.cells()
                 for mp in ((args.multi_pod,) if args.single_mesh
                            else (False, True))]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all)")
        cells = [(configs.canonical(args.arch), args.shape, args.multi_pod)]

    results, failures = [], 0
    for arch, shape, mp in cells:
        label = f"{arch:28s} {shape:12s} {'2x16x16' if mp else '16x16'}"
        over = PROD_OVERRIDES.get(arch, {})
        try:
            r = run_cell(arch, shape, multi_pod=mp, unroll=args.unroll,
                         microbatches=args.microbatches,
                         seq_shard=not args.no_seq_shard, **over)
            print(f"[ok]   {label}  peak/dev={r['memory']['peak_bytes']/2**30:7.2f} GiB"
                  f" ({100*r['hbm_frac']:5.1f}% HBM)"
                  f"  flops={r['cost']['flops']:.3e}"
                  f"  coll={r['collectives'].get('total_bytes', 0)/2**20:9.1f} MiB"
                  f"  {r['compile_s']:6.1f}s", flush=True)
        except Exception as e:
            failures += 1
            r = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if mp else "16x16", "ok": False,
                 "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {label}  {type(e).__name__}: {e}", flush=True)
            traceback.print_exc(limit=3)
        results.append(r)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")
    print(f"\n{len(results) - failures}/{len(results)} cells compiled")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

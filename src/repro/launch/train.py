"""Production trainer entry point.

Fault tolerance:
* sharded atomic checkpoints (``repro.train.checkpoint``) written by an
  async thread every ``--ckpt-every`` steps, newest ``--keep`` retained;
* SIGTERM/SIGINT (preemption) triggers a final checkpoint before exit;
* ``--resume`` restores the newest complete checkpoint — parameters,
  optimizer moments, AND the data-loader cursor — and replays bitwise
  identically (the loader is a pure function of (seed, step));
* elasticity: checkpoints are topology-agnostic; restoring onto a
  different mesh re-lays leaves out via the current sharding rules.

Smoke scale runs on CPU:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 20 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time
from typing import Optional

import jax
import numpy as np

from .. import configs
from ..data import LoaderConfig, TrainLoader
from ..sharding import local_context
from ..train import (AsyncCheckpointer, OptConfig, TrainConfig,
                     build_train_step, latest, load, make_train_state)


def train_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, smoke=args.smoke)
    if not args.smoke:
        print("WARNING: full config on this host — expect OOM; "
              "use the cluster launcher / --smoke locally", file=sys.stderr)
    mesh_ctx = local_context()
    tc = TrainConfig(opt=OptConfig(lr=args.lr, total_steps=args.steps,
                                   warmup_steps=max(args.steps // 10, 1)),
                     microbatches=args.microbatches)

    state = make_train_state(cfg, tc, jax.random.key(args.seed))
    loader = TrainLoader(LoaderConfig(global_batch=args.global_batch,
                                      seq_len=args.seq_len, vocab=cfg.vocab,
                                      seed=args.seed))
    start_step = 0
    ckpt: Optional[AsyncCheckpointer] = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir, keep=args.keep)
        if args.resume:
            path = latest(args.ckpt_dir)
            if path:
                start_step, payload = load(path)
                state = payload["state"]
                loader.load_state_dict(payload["loader"])
                print(f"resumed from {path} at step {start_step}")

    step_fn = jax.jit(build_train_step(cfg, tc, mesh_ctx),
                      donate_argnums=(0,))

    preempted = {"flag": False}

    def on_term(signum, frame):
        preempted["flag"] = True

    old = signal.signal(signal.SIGTERM, on_term)

    t0 = time.time()
    step = start_step
    try:
        for step in range(start_step, args.steps):
            batch = loader.build_batch(step)
            state, metrics = step_fn(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"step {step:5d}  loss {m['loss']:.4f}  "
                      f"gnorm {m['grad_norm']:.3f}  lr {m['lr']:.2e}  "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            done = step + 1
            if ckpt and (done % args.ckpt_every == 0 or preempted["flag"]):
                loader_state = {"next_step": done}
                ckpt.save(done, {"state": state, "loader": loader_state})
            if preempted["flag"]:
                print(f"preempted at step {done}; checkpoint written")
                break
    finally:
        if ckpt:
            ckpt.wait()
        signal.signal(signal.SIGTERM, old)
    return 0


if __name__ == "__main__":
    sys.exit(train_main())

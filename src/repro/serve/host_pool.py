"""Host-memory KV block pool — tier 1 of the serving data plane.

Mirrors ``serve.kv_pool.KVBlockPool`` on the host side: one preallocated
numpy buffer per KV cache leaf, shaped ``(*lead, num_blocks, block_tokens,
KV, D)``, plus a free list of row indices. A demoted prefix-cache block
occupies ONE row across every leaf, so the tiered store's payloads stay
single ints in both tiers.

PR 8 adds a **quantized mode**: with a ``quant`` spec the buffers store
1-byte elements (int8 / float8_e4m3fn) plus one f32 scale per
(row, layer-sub-block), and rows are exchanged with the device pool in
``KVBlockPool.read_rows(quant=...)``'s ``(blocks, scales)`` pair format.
``block_nbytes`` then prices the *transcoded* row — a byte budget buys
``compression_ratio``-times more blocks, which is the whole point: the
paper's all-or-nothing property makes complete chains per byte, not raw
bytes, the capacity that matters.

Unlike the device pool this tier never grows: its size is the operator's
``--host-cache-kb`` budget, and the tiered store's second eviction index
frees rows before the byte budget is exceeded (blocks are uniform-size, so
byte-room implies row-room). Buffers are ordinary preallocated numpy
arrays — on CUDA-class runtimes they would be page-locked (pinned) host
allocations; the allocation pattern (preallocate once, reuse rows) is what
keeps demotion/promotion copies from churning the allocator either way.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import numpy as np

from .. import quant as quantlib
from ..quant import QuantSpec
from .kv_pool import (KVBlockPool, _pool_leaf_shape, _row_axis,
                      quant_chain_block_nbytes)


class HostBlockPool:
    """Preallocated host-side paged block pool over an engine's KV cache
    pytree, optionally storing rows quantized. Rows are exchanged with a
    ``KVBlockPool`` via its ``read_rows``/``write_rows`` stacked-block
    format (the ``(blocks, scales)`` pair variant when quantized)."""

    def __init__(self, cache_template, block_tokens: int, num_blocks: int,
                 quant: Optional[QuantSpec] = None) -> None:
        self.block_tokens = block_tokens
        self.num_blocks = max(int(num_blocks), 0)
        self.quant = quant
        self.buffers = jax.tree.map(
            lambda leaf: self._alloc_buffer(
                _pool_leaf_shape(leaf.shape, self.num_blocks, block_tokens),
                quant.dtype if quant is not None else leaf.dtype),
            cache_template)
        if quant is not None:
            # one f32 scale per (row, *lead) sub-block; tiny, always RAM
            self.scales = jax.tree.map(
                lambda leaf: np.zeros((self.num_blocks,) + leaf.shape[:-4],
                                      quantlib.SCALE_DTYPE),
                cache_template)
        else:
            self.scales = None
        self.block_nbytes = quant_chain_block_nbytes(
            cache_template, block_tokens, quant)
        self.free_list: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.high_water = 0           # max rows ever simultaneously in use

    # subclass hook: DiskBlockPool swaps np.zeros for an np.memmap
    def _alloc_buffer(self, shape, dtype) -> np.ndarray:
        return np.zeros(shape, dtype)

    @classmethod
    def for_device_pool(cls, cache_template, device_pool: KVBlockPool,
                        capacity_bytes: int,
                        quant: Optional[QuantSpec] = None,
                        **kwargs) -> "HostBlockPool":
        """Size a pool to a byte budget, in whole blocks priced at the
        TRANSCODED row size — the same budget holds ~``itemsize`` times
        more blocks when quantized."""
        blk = quant_chain_block_nbytes(cache_template,
                                       device_pool.block_tokens, quant)
        num = capacity_bytes // max(blk, 1)
        return cls(cache_template, device_pool.block_tokens, num,
                   quant=quant, **kwargs)

    # -------------------------------------------------------------- indices
    def alloc(self) -> int:
        idx = self.free_list.pop()      # tiered store guarantees room
        self.high_water = max(self.high_water, self.blocks_in_use)
        return idx

    def free(self, idx: int) -> None:
        self.free_list.append(int(idx))

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self.free_list)

    @property
    def bytes_in_use(self) -> int:
        return self.blocks_in_use * self.block_nbytes

    # ------------------------------------------------------------ transfers
    def read_rows(self, idxs: List[int]):
        """Stacked per-leaf copies of rows ``idxs`` (numpy fancy indexing
        copies), row axis leading — the host half of a promotion; feed the
        result to ``KVBlockPool.write_rows``. Quantized pools return the
        ``(blocks, scales)`` pair the device scatter dequantizes from."""
        sel = np.asarray(idxs, np.int64)

        def take(hbuf):
            lead = _row_axis(hbuf)
            return np.moveaxis(np.take(hbuf, sel, axis=lead), lead, 0)

        blocks = jax.tree.map(take, self.buffers)
        if self.quant is None:
            return blocks
        return blocks, jax.tree.map(lambda s: s[sel], self.scales)

    def write_rows(self, idxs: List[int], host_blocks,
                   scales=None) -> None:
        """Store stacked per-leaf block arrays (``KVBlockPool.read_rows``
        output, row axis leading) into rows ``idxs`` — the host half of a
        demotion. Quantized pools additionally store the per-row
        ``scales`` pytree the transcoding read produced."""
        assert (scales is None) == (self.quant is None), \
            "scales must accompany writes exactly when the pool quantizes"
        sel = np.asarray(idxs, np.int64)

        def put(hbuf, blk):
            lead = _row_axis(hbuf)
            ix = (slice(None),) * lead + (sel,)
            hbuf[ix] = np.moveaxis(np.asarray(blk, dtype=hbuf.dtype),
                                   0, lead)

        jax.tree.map(put, self.buffers, host_blocks)
        if scales is not None:
            jax.tree.map(lambda sbuf, s: sbuf.__setitem__(sel, s),
                         self.scales, scales)

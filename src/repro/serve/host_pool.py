"""Host-memory KV block pool — tier 1 of the serving data plane.

Mirrors ``serve.kv_pool.KVBlockPool`` on the host side: one preallocated
numpy buffer per KV cache leaf, shaped ``(*lead, num_blocks, block_tokens,
KV, D)``, plus a free list of row indices. A demoted prefix-cache block
occupies ONE row across every leaf, so the tiered store's payloads stay
single ints in both tiers.

Unlike the device pool this tier never grows: its size is the operator's
``--host-cache-kb`` budget, and the tiered store's second eviction index
frees rows before the byte budget is exceeded (blocks are uniform-size, so
byte-room implies row-room). Buffers are ordinary preallocated numpy
arrays — on CUDA-class runtimes they would be page-locked (pinned) host
allocations; the allocation pattern (preallocate once, reuse rows) is what
keeps demotion/promotion copies from churning the allocator either way.
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from .kv_pool import KVBlockPool, _pool_leaf_shape, _row_axis


class HostBlockPool:
    """Preallocated host-side paged block pool over an engine's KV cache
    pytree. Rows are exchanged with a ``KVBlockPool`` via its
    ``read_rows``/``write_rows`` stacked-block format."""

    def __init__(self, cache_template, block_tokens: int,
                 num_blocks: int) -> None:
        self.block_tokens = block_tokens
        self.num_blocks = max(int(num_blocks), 0)
        self.buffers = jax.tree.map(
            lambda leaf: np.zeros(
                _pool_leaf_shape(leaf.shape, self.num_blocks, block_tokens),
                leaf.dtype),
            cache_template)
        self.free_list: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.high_water = 0           # max rows ever simultaneously in use

    @classmethod
    def for_device_pool(cls, cache_template, device_pool: KVBlockPool,
                        capacity_bytes: int) -> "HostBlockPool":
        """Size a host pool to a byte budget, in whole blocks of the same
        shape as ``device_pool``'s rows."""
        num = capacity_bytes // max(device_pool.block_nbytes, 1)
        return cls(cache_template, device_pool.block_tokens, num)

    # -------------------------------------------------------------- indices
    def alloc(self) -> int:
        idx = self.free_list.pop()      # tiered store guarantees room
        self.high_water = max(self.high_water, self.blocks_in_use)
        return idx

    def free(self, idx: int) -> None:
        self.free_list.append(int(idx))

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self.free_list)

    # ------------------------------------------------------------ transfers
    def read_rows(self, idxs: List[int]):
        """Stacked per-leaf copies of rows ``idxs`` (numpy fancy indexing
        copies), row axis leading — the host half of a promotion; feed the
        result to ``KVBlockPool.write_rows``."""
        sel = np.asarray(idxs, np.int64)

        def take(hbuf):
            lead = _row_axis(hbuf)
            return np.moveaxis(np.take(hbuf, sel, axis=lead), lead, 0)

        return jax.tree.map(take, self.buffers)

    def write_rows(self, idxs: List[int], host_blocks) -> None:
        """Store stacked per-leaf block arrays (``KVBlockPool.read_rows``
        output, row axis leading) into rows ``idxs`` — the host half of a
        demotion."""
        sel = np.asarray(idxs, np.int64)

        def put(hbuf, blk):
            lead = _row_axis(hbuf)
            ix = (slice(None),) * lead + (sel,)
            hbuf[ix] = np.moveaxis(np.asarray(blk, dtype=hbuf.dtype),
                                   0, lead)

        jax.tree.map(put, self.buffers, host_blocks)

"""Device-resident paged KV block pool.

The serving data plane's ONLY KV storage: one preallocated device buffer
per KV cache leaf, shaped ``(*lead, num_blocks, block_tokens, KV, D)``
(with ``lead`` the leaf's leading layer-stack axes — the row axis sits
right where a per-layer scan slice lands), plus a host-side free list and
per-row reference counts. A ``PrefixStore`` payload is ONE ``int`` — the
pool row holding that chain block's KV for every layer.

Two engines share this pool class:

* the **paged** engine (PR 5) decodes straight out of the pool via
  per-slot block tables: a prefix hit is a host-side table write (zero
  dispatches, zero copies), publish transfers ownership of already-written
  rows to the store (``share``), and eviction drops a reference — rows are
  reclaimed when the last referent (store, or an engine slot still reading
  the row) lets go;
* the **gather** engine (PR 2, retained as the fallback for non-uniform
  layer patterns) copies chains pool→slot on a hit (``gather_into``) and
  slot→pool on publish (``scatter_from``); every row then has exactly one
  referent and ``free`` is the O(1) reclaim it always was.

Transfers are shape-specialized by the number of blocks moved (chain
lengths are bounded by ``max_seq / block_tokens``, so the trace cache
stays small); pool-mutating ops donate the pool buffers so XLA updates
rows in place. When the free list runs dry under an unbounded-capacity
store the pool doubles — byte-capacity-driven eviction normally frees
indices before that happens.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import quant as quantlib
from ..quant import QuantSpec


def _pool_leaf_shape(leaf_shape: Tuple[int, ...], num_blocks: int,
                     block_tokens: int) -> Tuple[int, ...]:
    """Cache leaf (*lead, B, S, KV, D) -> pool (*lead, nb, bt, KV, D)."""
    return leaf_shape[:-4] + (num_blocks, block_tokens) + leaf_shape[-2:]


def _row_axis(pbuf) -> int:
    """The row axis of a pool leaf (after any layer-stack lead axes)."""
    return pbuf.ndim - 4


def chain_block_nbytes(cache_template, block_tokens: int) -> int:
    """Bytes of ONE chain block across every KV leaf of ``cache_template``
    (leaves shaped (*lead, B, S, KV, D)) — the store's nbytes_per_block.
    The single source of truth for pool sizing AND byte accounting."""
    return sum(leaf.nbytes // (leaf.shape[-4] * leaf.shape[-3])
               * block_tokens
               for leaf in jax.tree.leaves(cache_template))


def quant_chain_block_nbytes(cache_template, block_tokens: int,
                             spec: Optional[QuantSpec]) -> int:
    """Bytes of ONE *transcoded* chain block: narrow payload plus one f32
    scale per (layer-stack) sub-block of every leaf. This is the number a
    quantized tier's byte budget divides by — the whole capacity-per-byte
    win of the compressed hierarchy is this quantity shrinking."""
    if spec is None:
        return chain_block_nbytes(cache_template, block_tokens)
    total = 0
    for leaf in jax.tree.leaves(cache_template):
        lead_numel = 1
        for d in leaf.shape[:-4]:
            lead_numel *= d
        block_numel = (lead_numel * block_tokens
                       * leaf.shape[-2] * leaf.shape[-1])
        total += (spec.itemsize * block_numel
                  + quantlib.SCALE_DTYPE.itemsize * lead_numel)
    return total


@partial(jax.jit, donate_argnums=0)
def _gather(cache, pool, idxs, slot):
    """Write pool blocks ``idxs`` into ``slot``'s cache rows at token
    positions [0, n*bt) — the restored chain is contiguous from 0."""

    def write(leaf, pbuf):
        lead = _row_axis(pbuf)
        n, bt = idxs.shape[0], pbuf.shape[-3]
        blocks = jnp.take(pbuf, idxs, axis=lead)    # (*lead, n, bt, KV, D)
        chain = blocks.reshape(blocks.shape[:lead] + (n * bt,)
                               + blocks.shape[-2:])
        upd = jnp.expand_dims(chain, lead)          # (*lead, 1, n*bt, KV, D)
        starts = (0,) * lead + (slot, 0, 0, 0)
        return jax.lax.dynamic_update_slice(leaf, upd.astype(leaf.dtype),
                                            starts)

    return jax.tree.map(write, cache, pool)


@jax.jit
def _read_rows(pool, idxs):
    """Gather pool rows ``idxs`` into one stacked (n, *lead, bt, KV, D)
    array per leaf — the on-device half of a demotion (the host copy is a
    single device_get)."""

    def read(pbuf):
        lead = _row_axis(pbuf)
        return jnp.moveaxis(jnp.take(pbuf, idxs, axis=lead), lead, 0)

    return jax.tree.map(read, pool)


@partial(jax.jit, static_argnames=("spec",))
def _read_rows_quant(pool, idxs, spec):
    """Gather + quantize in one dispatch: pool rows ``idxs`` come back as
    ``(blocks, scales)`` pytrees — blocks in ``spec.dtype`` shaped
    ``(n, *lead, bt, KV, D)``, f32 scales shaped ``(n, *lead)`` (one per
    layer sub-block). On a sharded pool the amax reduction spans the KV
    shards (an exact max all-reduce), so every replica would compute the
    identical scale. Only the narrow bytes + scales then cross to host."""

    def read(pbuf):
        lead = _row_axis(pbuf)
        rows = jnp.moveaxis(jnp.take(pbuf, idxs, axis=lead), lead, 0)
        return quantlib.quantize_blocks(rows, spec)

    pairs = jax.tree.map(read, pool)
    is_pair = lambda t: isinstance(t, tuple)                      # noqa: E731
    return (jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair),
            jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair))


@partial(jax.jit, donate_argnums=0)
def _write_rows_dequant(pool, blocks, scales, idxs):
    """Dequantize + scatter in one dispatch — the device half of a
    promotion from a quantized tier. The narrow bytes cross the PCIe
    boundary; widening happens on device."""

    def write(pbuf, blk, sc):
        lead = _row_axis(pbuf)
        wide = quantlib.dequantize_blocks(blk, sc, pbuf.dtype)
        ix = (slice(None),) * lead + (idxs,)
        return pbuf.at[ix].set(jnp.moveaxis(wide, 0, lead))

    return jax.tree.map(write, pool, blocks, scales)


@partial(jax.jit, donate_argnums=0)
def _write_rows(pool, blocks, idxs):
    """Scatter stacked (n, *lead, bt, KV, D) block arrays into pool rows
    ``idxs`` — the on-device half of a promotion (host arrays cross in
    the jit call)."""

    def write(pbuf, blk):
        lead = _row_axis(pbuf)
        ix = (slice(None),) * lead + (idxs,)
        return pbuf.at[ix].set(jnp.moveaxis(blk, 0, lead)
                               .astype(pbuf.dtype))

    return jax.tree.map(write, pool, blocks)


@partial(jax.jit, donate_argnums=1)
def _scatter(cache, pool, idxs, starts, slot):
    """Read blocks at token offsets ``starts`` from ``slot``'s cache rows
    into pool rows ``idxs`` (fresh blocks need not be contiguous: resident
    prefix blocks are skipped by the store)."""

    def read_write(leaf, pbuf):
        bt = pbuf.shape[-3]
        lead = _row_axis(pbuf)
        row = jax.lax.dynamic_index_in_dim(leaf, slot, axis=lead,
                                           keepdims=False)

        def block_at(t0):
            return jax.lax.dynamic_slice_in_dim(row, t0, bt, axis=lead)

        blocks = jax.vmap(block_at)(starts)         # (n, *lead, bt, KV, D)
        ix = (slice(None),) * lead + (idxs,)
        return pbuf.at[ix].set(jnp.moveaxis(blocks, 0, lead)
                               .astype(pbuf.dtype))

    return jax.tree.map(read_write, cache, pool)


@partial(jax.jit, donate_argnums=0)
def _copy_row(pool, src, dst):
    """Duplicate pool row ``src`` into ``dst`` — copy-on-write for the
    paged engine when a fully-resident chain's last block must absorb the
    recomputed final prompt token without touching the store's copy."""

    def cp(pbuf):
        lead = _row_axis(pbuf)
        row = jax.lax.dynamic_index_in_dim(pbuf, src, axis=lead,
                                           keepdims=True)
        return jax.lax.dynamic_update_slice_in_dim(pbuf, row, dst,
                                                   axis=lead)

    return jax.tree.map(cp, pool)


class KVBlockPool:
    """Refcounted paged block pool over an engine's KV cache pytree.

    With ``shard_ctx`` (a ``sharding.KVShardCtx``, PR 7) every leaf is
    laid out with a ``NamedSharding`` splitting the KV-head dim over the
    mesh's ``model`` axis: one *global* row still means one chain block,
    but its bytes (and the decode compute that reads them) span devices.
    The free list, refcounts, and every row index stay host-side and
    device-count-invariant — the policy layer cannot tell the pool is
    sharded.
    """

    def __init__(self, cache_template, block_tokens: int,
                 num_blocks: int, shard_ctx=None) -> None:
        self.block_tokens = block_tokens
        self.num_blocks = max(int(num_blocks), 1)
        self.shard_ctx = shard_ctx
        if shard_ctx is not None:
            for leaf in jax.tree.leaves(cache_template):
                if leaf.shape[-2] % shard_ctx.tp:
                    raise ValueError(
                        f"KV pool leaf with {leaf.shape[-2]} KV heads "
                        f"cannot shard over tp={shard_ctx.tp}")
        self.buffers = jax.tree.map(
            lambda leaf: self._committed(jnp.zeros(
                _pool_leaf_shape(leaf.shape, self.num_blocks, block_tokens),
                leaf.dtype)),
            cache_template)
        self.free_list: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.refs: List[int] = [0] * self.num_blocks
        self.block_nbytes = chain_block_nbytes(cache_template, block_tokens)
        self.grows = 0
        self.high_water = 0           # max rows ever simultaneously in use

    def _committed(self, arr):
        """Commit an array to the pool's sharding (no-op when unsharded).
        Works for pool leaves AND stacked row batches — the sharded KV dim
        is at -2 in both layouts."""
        if self.shard_ctx is None:
            return arr
        return jax.device_put(arr, self.shard_ctx.pool_sharding(arr.ndim))

    # -------------------------------------------------------------- indices
    def alloc(self) -> int:
        if not self.free_list:
            self._grow()
        idx = self.free_list.pop()
        self.refs[idx] = 1
        self.high_water = max(self.high_water, self.blocks_in_use)
        return idx

    def share(self, idx: Any) -> int:
        """Take another reference on a live row (a slot's block table
        entry, or store ownership at publish). Returns the row."""
        idx = int(idx)
        assert self.refs[idx] > 0, f"share of free row {idx}"
        self.refs[idx] += 1
        return idx

    def free(self, idx: Any) -> None:
        """Drop one reference; the row returns to the free list when the
        last referent (store or engine slot) lets go."""
        idx = int(idx)
        self.refs[idx] -= 1
        assert self.refs[idx] >= 0, f"double free of row {idx}"
        if self.refs[idx] == 0:
            self.free_list.append(idx)

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self.free_list)

    @property
    def tp(self) -> int:
        return self.shard_ctx.tp if self.shard_ctx is not None else 1

    @property
    def nbytes(self) -> int:
        """GLOBAL pool bytes, summed across every shard (the quantity the
        store's byte budget prices)."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.buffers))

    @property
    def nbytes_per_device(self) -> int:
        """Bytes one device actually holds: nbytes / tp (exact — leaf
        construction checked KV-head divisibility)."""
        return self.nbytes // self.tp

    def _grow(self) -> None:
        """Double the pool (unbounded-capacity stores never evict, so the
        byte budget cannot free indices for us)."""
        old = self.num_blocks
        self.num_blocks = old * 2
        self.buffers = jax.tree.map(
            lambda pbuf: self._committed(jnp.concatenate(
                [pbuf, jnp.zeros_like(pbuf)], axis=_row_axis(pbuf))),
            self.buffers)
        self.free_list.extend(range(self.num_blocks - 1, old - 1, -1))
        self.refs.extend([0] * old)
        self.grows += 1

    # ------------------------------------------------------------ transfers
    def gather_into(self, cache, slot: int, idxs: List[int]):
        """Restore chain blocks ``idxs`` into ``slot``; returns the updated
        cache. Device-to-device only. (Gather-engine hit path.)"""
        return _gather(cache, self.buffers,
                       jnp.asarray(idxs, jnp.int32), jnp.int32(slot))

    def scatter_from(self, cache, slot: int, block_positions: List[int],
                     idxs: List[int]) -> None:
        """Capture the blocks at chain positions ``block_positions`` of
        ``slot``'s cache into pool rows ``idxs``. Device-to-device only.
        (Gather-engine publish path.)"""
        starts = jnp.asarray([p * self.block_tokens
                              for p in block_positions], jnp.int32)
        self.buffers = _scatter(cache, self.buffers,
                                jnp.asarray(idxs, jnp.int32), starts,
                                jnp.int32(slot))

    def copy_row(self, src: int, dst: int) -> None:
        """One-row device copy (paged-engine copy-on-write)."""
        self.buffers = _copy_row(self.buffers, jnp.int32(src),
                                 jnp.int32(dst))

    # -------------------------------------------- host-tier transfers (PR 4)
    # Like gather/scatter above, both directions shape-specialize on the
    # number of rows moved: demotion batches are bounded by the victims of
    # one _make_room call and promotion batches by max_seq / block_tokens,
    # so the trace cache stays small.
    def read_rows(self, idxs: List[int], quant: Optional[QuantSpec] = None):
        """Copy pool rows ``idxs`` to host memory: one jitted gather per
        leaf, then a single device_get of the stacked result. Returns a
        pytree of numpy arrays shaped ``(len(idxs), *lead, bt, KV, D)``.

        With ``quant`` the gather *transcodes*: rows quantize on device
        (per-layer-per-block f32 scales over each leaf's trailing
        ``(bt, KV, D)`` axes) and the return value is a ``(blocks,
        scales)`` pair of pytrees — only 1-byte elements plus the tiny
        scale arrays cross the device boundary."""
        sel = jnp.asarray(idxs, jnp.int32)
        if quant is None:
            return jax.device_get(_read_rows(self.buffers, sel))
        return jax.device_get(_read_rows_quant(self.buffers, sel, quant))

    def write_rows(self, idxs: List[int], host_blocks,
                   scales=None) -> None:
        """Scatter host-side stacked block arrays (the pytree shape
        ``read_rows`` returns) into pool rows ``idxs``. The host→device
        transfer happens inside the jit call; on a sharded pool the
        stacked rows are committed to the matching KV-head sharding first
        (each device receives only its head slice — the host tier itself
        stays global-shape and device-invariant).

        With ``scales`` (the pair a quantized-tier read produced) the
        scatter dequantizes on device after the narrow bytes cross.
        Either way the whole batch commits as ONE ``device_put`` of the
        stacked pytree (leaf transfers batched in a single call, not one
        per leaf) + one jitted scatter, regardless of leaf count — the
        store counts these dispatches as ``promotion_dispatches``."""
        sel = jnp.asarray(idxs, jnp.int32)
        if self.shard_ctx is None:
            host_blocks = jax.device_put(host_blocks)
        else:
            host_blocks = jax.device_put(
                host_blocks,
                jax.tree.map(lambda a: self.shard_ctx.pool_sharding(a.ndim),
                             host_blocks))
        if scales is None:
            self.buffers = _write_rows(self.buffers, host_blocks, sel)
            return
        if self.shard_ctx is None:
            scales = jax.device_put(scales)
        else:
            # scales are per-(row, layer) — no KV dim; replicate them.
            rep = self.shard_ctx.replicated()
            scales = jax.device_put(scales,
                                    jax.tree.map(lambda _: rep, scales))
        self.buffers = _write_rows_dequant(self.buffers, host_blocks,
                                           scales, sel)

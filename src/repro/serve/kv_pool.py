"""Device-resident paged KV block pool.

The serving data plane's block storage: one preallocated device buffer per
KV cache leaf, shaped ``(num_blocks, *lead, block_tokens, KV, D)`` (with
``lead`` the leaf's leading layer-stack axes), plus a host-side free list
of block indices. A ``PrefixStore`` payload is then ONE ``int`` — the pool
row holding that chain block's KV for every layer — so:

* a prefix-cache **hit** is a jitted gather pool→slot (one
  dynamic-update-slice per leaf, the chain is contiguous from position 0);
* an **insert** is a jitted scatter slot→pool of exactly the fresh blocks;
* an **eviction** is ``free(idx)`` — O(1), zero copies, and no KV bytes
  ever round-trip through host memory.

Both transfers are shape-specialized by the number of blocks moved (chain
lengths are bounded by ``max_seq / block_tokens``, so the trace cache
stays small). When the free list runs dry under an unbounded-capacity
store the pool doubles — byte-capacity-driven eviction normally frees
indices before that happens.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp


def _pool_leaf_shape(leaf_shape: Tuple[int, ...], num_blocks: int,
                     block_tokens: int) -> Tuple[int, ...]:
    """Cache leaf (*lead, B, S, KV, D) -> pool (num_blocks, *lead, bt, KV, D)."""
    return (num_blocks,) + leaf_shape[:-4] + (block_tokens,) + leaf_shape[-2:]


def chain_block_nbytes(cache_template, block_tokens: int) -> int:
    """Bytes of ONE chain block across every KV leaf of ``cache_template``
    (leaves shaped (*lead, B, S, KV, D)) — the store's nbytes_per_block.
    The single source of truth for pool sizing AND byte accounting."""
    return sum(leaf.nbytes // (leaf.shape[-4] * leaf.shape[-3])
               * block_tokens
               for leaf in jax.tree.leaves(cache_template))


@jax.jit
def _gather(cache, pool, idxs, slot):
    """Write pool blocks ``idxs`` into ``slot``'s cache rows at token
    positions [0, n*bt) — the restored chain is contiguous from 0."""

    def write(leaf, pbuf):
        n, bt = idxs.shape[0], pbuf.shape[-3]
        blocks = pbuf[idxs]                         # (n, *lead, bt, KV, D)
        lead = blocks.ndim - 4
        blocks = jnp.moveaxis(blocks, 0, lead)      # (*lead, n, bt, KV, D)
        chain = blocks.reshape(blocks.shape[:lead] + (n * bt,)
                               + blocks.shape[-2:])
        upd = jnp.expand_dims(chain, lead)          # (*lead, 1, n*bt, KV, D)
        starts = (0,) * lead + (slot, 0, 0, 0)
        return jax.lax.dynamic_update_slice(leaf, upd.astype(leaf.dtype),
                                            starts)

    return jax.tree.map(write, cache, pool)


@jax.jit
def _read_rows(pool, idxs):
    """Gather pool rows ``idxs`` into one stacked array per leaf — the
    on-device half of a demotion (the host copy is a single device_get)."""
    return jax.tree.map(lambda pbuf: pbuf[idxs], pool)


@jax.jit
def _write_rows(pool, blocks, idxs):
    """Scatter stacked per-leaf block arrays into pool rows ``idxs`` — the
    on-device half of a promotion (host arrays cross in the jit call)."""
    return jax.tree.map(
        lambda pbuf, blk: pbuf.at[idxs].set(blk.astype(pbuf.dtype)),
        pool, blocks)


@jax.jit
def _scatter(cache, pool, idxs, starts, slot):
    """Read blocks at token offsets ``starts`` from ``slot``'s cache rows
    into pool rows ``idxs`` (fresh blocks need not be contiguous: resident
    prefix blocks are skipped by the store)."""

    def read_write(leaf, pbuf):
        bt = pbuf.shape[-3]
        lead = leaf.ndim - 4
        row = jax.lax.dynamic_index_in_dim(leaf, slot, axis=lead,
                                           keepdims=False)

        def block_at(t0):
            return jax.lax.dynamic_slice_in_dim(row, t0, bt, axis=lead)

        blocks = jax.vmap(block_at)(starts)         # (n, *lead, bt, KV, D)
        return pbuf.at[idxs].set(blocks.astype(pbuf.dtype))

    return jax.tree.map(read_write, cache, pool)


class KVBlockPool:
    """Paged block pool over an engine's KV cache pytree."""

    def __init__(self, cache_template, block_tokens: int,
                 num_blocks: int) -> None:
        self.block_tokens = block_tokens
        self.num_blocks = max(int(num_blocks), 1)
        self.buffers = jax.tree.map(
            lambda leaf: jnp.zeros(
                _pool_leaf_shape(leaf.shape, self.num_blocks, block_tokens),
                leaf.dtype),
            cache_template)
        self.free_list: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self.block_nbytes = chain_block_nbytes(cache_template, block_tokens)
        self.grows = 0
        self.high_water = 0           # max rows ever simultaneously in use

    # -------------------------------------------------------------- indices
    def alloc(self) -> int:
        if not self.free_list:
            self._grow()
        idx = self.free_list.pop()
        self.high_water = max(self.high_water, self.blocks_in_use)
        return idx

    def free(self, idx: Any) -> None:
        self.free_list.append(int(idx))

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self.free_list)

    def _grow(self) -> None:
        """Double the pool (unbounded-capacity stores never evict, so the
        byte budget cannot free indices for us)."""
        old = self.num_blocks
        self.num_blocks = old * 2
        self.buffers = jax.tree.map(
            lambda pbuf: jnp.concatenate(
                [pbuf, jnp.zeros_like(pbuf)], axis=0),
            self.buffers)
        self.free_list.extend(range(self.num_blocks - 1, old - 1, -1))
        self.grows += 1

    # ------------------------------------------------------------ transfers
    def gather_into(self, cache, slot: int, idxs: List[int]):
        """Restore chain blocks ``idxs`` into ``slot``; returns the updated
        cache. Device-to-device only."""
        return _gather(cache, self.buffers,
                       jnp.asarray(idxs, jnp.int32), jnp.int32(slot))

    def scatter_from(self, cache, slot: int, block_positions: List[int],
                     idxs: List[int]) -> None:
        """Capture the blocks at chain positions ``block_positions`` of
        ``slot``'s cache into pool rows ``idxs``. Device-to-device only."""
        starts = jnp.asarray([p * self.block_tokens
                              for p in block_positions], jnp.int32)
        self.buffers = _scatter(cache, self.buffers,
                                jnp.asarray(idxs, jnp.int32), starts,
                                jnp.int32(slot))

    # -------------------------------------------- host-tier transfers (PR 4)
    # Like gather/scatter above, both directions shape-specialize on the
    # number of rows moved: demotion batches are bounded by the victims of
    # one _make_room call and promotion batches by max_seq / block_tokens,
    # so the trace cache stays small.
    def read_rows(self, idxs: List[int]):
        """Copy pool rows ``idxs`` to host memory: one jitted gather per
        leaf, then a single device_get of the stacked result. Returns a
        pytree of numpy arrays shaped ``(len(idxs), *lead, bt, KV, D)``."""
        return jax.device_get(
            _read_rows(self.buffers, jnp.asarray(idxs, jnp.int32)))

    def write_rows(self, idxs: List[int], host_blocks) -> None:
        """Scatter host-side stacked block arrays (the pytree shape
        ``read_rows`` returns) into pool rows ``idxs``. The host→device
        transfer happens inside the jit call."""
        self.buffers = _write_rows(self.buffers, host_blocks,
                                   jnp.asarray(idxs, jnp.int32))

"""Sharded multi-engine serve tier on the coordination plane.

``ShardedFrontend`` hash-routes request prefixes across K independent
``ServeEngine`` shards — each with its own ``PrefixStore`` + ``KVBlockPool``
— and registers every shard as a worker on one ``core.MessageBus``:

* **Routing** is by the request's first token block (``route_prefix``): a
  deterministic digest, stable across process restarts, so a prefix family
  always lands on the same shard (affinity) and its KV chain is reused
  there. Prompts shorter than one block route on the whole prompt.
* **Coordination**: each request's chain is announced to the
  ``PeerTrackerMaster`` as a peer-information profile (chain nodes are
  blocks, per-position prefixes are peer groups — namespaced ``s{k}:`` per
  shard so one global DAG spans all shards); every store event (resident,
  evicted, request retired, skeleton GC) flows over the bus, and evictions
  that break a complete peer group run the paper's report/broadcast
  protocol. The protocol *level* follows the store policy exactly as in
  ``sim.ClusterSim``: a DAG-oblivious tier ships no peer profiles and a
  completeness-oblivious one no eviction reports — replicas then track
  residency only, via the legacy status channel. Every shard therefore
  holds a live ERC replica of the WHOLE
  tier: a chain resident across shards is just a peer group whose members
  carry different namespaces, and cross-shard evictions keep all replicas
  coherent (``verify_replicas`` proves it against each shard's own store
  state).

Generation is exact under sharding: greedy decoding with KV-exact prefix
restore means K-shard output is token-identical to the single engine
(``tests/test_sharded_serve.py`` proves shards ∈ {1,2,4} byte-equal).
"""
from __future__ import annotations

import hashlib
import itertools
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core import (BlockMeta, CacheMetrics, JobDAG, MessageBus, PeerTracker,
                    PeerTrackerMaster, TaskSpec)
from ..faults import FaultInjector, FaultPlan
from ..obs.trace import TID_BUS as _TID_BUS, TID_ENGINE as _TID_ENGINE
from .engine import Request, ServeEngine
from .prefix_store import PrefixStore
from .scheduler import Scheduler, StepCostModel
from .tiered import TieredKVStore


def route_prefix(tokens: Sequence[int], n_shards: int,
                 block_tokens: int) -> int:
    """Stable shard for a request: digest of its first token block.

    Uses blake2b (unsalted, unlike Python's ``hash``) so the mapping is
    identical across processes and restarts — the property that makes a
    warm shard's prefix cache survive a frontend restart.
    """
    head = tuple(int(t) for t in tokens[:block_tokens])
    digest = hashlib.blake2b(repr(head).encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big") % n_shards


class ShardedFrontend:
    """K ``ServeEngine`` shards behind one prefix-affinity router, all
    registered as workers of one coordination plane."""

    def __init__(self, cfg, params, n_shards: int = 2, *,
                 max_slots: int = 4, max_seq: int = 256,
                 capacity_bytes: int = 1 << 62, policy: str = "lerc",
                 block_tokens: int = 16, eos_id: int = -1,
                 prefill_chunk: int = 8,
                 pool_blocks: Optional[int] = None,
                 host_capacity_bytes: int = 0,
                 kv_quant: Optional[str] = None,
                 disk_capacity_bytes: int = 0,
                 disk_dir: Optional[str] = None,
                 paged: bool = False,
                 record_eviction_log: bool = False,
                 scheduler: Union[str, Scheduler, None] = None,
                 max_queue: Optional[int] = None,
                 clock: Optional[StepCostModel] = None,
                 eos_interval: int = 8, tp: int = 1,
                 stats_level: str = "full",
                 faults: Union[FaultPlan, FaultInjector, None] = None
                 ) -> None:
        assert n_shards >= 1
        self.n_shards = n_shards
        self.block_tokens = block_tokens
        if isinstance(faults, FaultPlan):
            faults = faults.injector()
        self.faults: Optional[FaultInjector] = faults
        self.failover_retries = 0
        self.shard_crashes_fired = 0
        self._recorder = None
        self.bus = MessageBus(record_log=False, stats_level=stats_level)
        self.bus.faults = faults
        self.trackers = [PeerTracker(k, self.bus) for k in range(n_shards)]
        for tr in self.trackers:
            # per-replica eviction logs are test/debug instrumentation;
            # a long-lived frontend keeps them off so memory stays bounded
            tr.record_eviction_log = record_eviction_log
        self.master = PeerTrackerMaster(self.bus, n_shards)
        self.shards: List[ServeEngine] = []
        self._distribute_profiles = True
        self._coordinated = True
        # everything a crash rebuild needs to reconstruct a shard's store
        # and engine from scratch (the replacement runs the same config)
        self._store_args = dict(
            capacity_bytes=capacity_bytes, policy=policy,
            block_tokens=block_tokens,
            host_capacity_bytes=host_capacity_bytes, kv_quant=kv_quant,
            disk_capacity_bytes=disk_capacity_bytes, disk_dir=disk_dir)
        self._engine_args = dict(
            max_slots=max_slots, max_seq=max_seq, eos_id=eos_id,
            prefill_chunk=prefill_chunk, pool_blocks=pool_blocks,
            paged=paged, scheduler=scheduler, max_queue=max_queue,
            clock=clock, eos_interval=eos_interval, tp=tp)
        self._cfg, self._params = cfg, params
        for k in range(n_shards):
            store = self._build_store(k)
            if k == 0:
                # protocol level is a tier-wide deployment choice derived
                # from the store policy, exactly as in sim.ClusterSim: a
                # DAG-oblivious shard ships no peer profiles and only a
                # completeness-aware one runs the report/bcast protocol
                self._distribute_profiles = store.policy.uses_dag
                self._coordinated = store.policy.uses_completeness
            self._wire(k, store)
            # shards (cache partitioning) and tp (tensor parallelism of
            # each shard's pool) compose: every engine shares one serve
            # mesh, so K shards × tp devices all hold 1/tp of each pool
            self.shards.append(self._build_engine(store))

    def _build_store(self, k: int) -> PrefixStore:
        a = self._store_args
        if a["host_capacity_bytes"] > 0:
            store: PrefixStore = TieredKVStore(
                a["capacity_bytes"], a["policy"],
                block_tokens=a["block_tokens"],
                host_capacity_bytes=a["host_capacity_bytes"],
                kv_quant=a["kv_quant"],
                disk_capacity_bytes=a["disk_capacity_bytes"],
                # each shard's memmap files live in their own subdir
                disk_dir=(os.path.join(a["disk_dir"], f"shard{k}")
                          if a["disk_dir"] else None))
            # attach BEFORE the engine builds the pools, so the disk pool
            # inherits the injector
            store.faults = self.faults
        else:
            store = PrefixStore(a["capacity_bytes"], a["policy"],
                                block_tokens=a["block_tokens"])
        return store

    def _build_engine(self, store: PrefixStore) -> ServeEngine:
        return ServeEngine(self._cfg, self._params, store=store,
                           **self._engine_args)

    # ------------------------------------------------------------------ obs
    def attach_trace(self, recorder) -> None:
        """Wire one ``TraceRecorder`` through the whole tier: each shard's
        engine becomes a pid of its own (``shard{k}``), and the
        coordination bus a final pid with its messages on the bus lane."""
        self._recorder = recorder
        for k, eng in enumerate(self.shards):
            eng.attach_trace(recorder, pid=k, name=f"shard{k}")
        recorder.label(self.n_shards, "bus", tid=_TID_BUS)
        self.bus.trace = recorder
        self.bus.trace_pid = self.n_shards

    # ---------------------------------------------------------- coordination
    def _ns(self, shard: int, ident: str) -> str:
        """Namespace a shard-local block/task id into the global DAG."""
        return f"s{shard}:{ident}"

    def _wire(self, shard: int, store: PrefixStore) -> None:
        tracker = self.trackers[shard]

        def on_evict(block_id: str, flipped: List[str]) -> None:
            # paper §III-C: report iff a complete peer group broke (the
            # master broadcasts, updating every shard's labels); the
            # eviction itself always rides the legacy status channel.
            # Only a completeness-aware policy deploys the LERC protocol.
            if self._coordinated:
                tracker.report_eviction(self._ns(shard, block_id),
                                        [self._ns(shard, t) for t in flipped])
            tracker.report_status("evicted", self._ns(shard, block_id))

        def on_status(event: str, ident: str) -> None:
            tracker.report_status(event, self._ns(shard, ident))

        store.on_evict = on_evict
        store.on_status = on_status

    def _announce(self, shard: int, store: PrefixStore, rid: int) -> None:
        """Broadcast a registered request's peer profile: its (namespaced)
        chain blocks + per-position peer-group tasks. The master dedupes
        against the composed DAG, so shared prefixes are announced once;
        newly created skeleton nodes are then reported materialized-on-disk
        (recomputable by prefill, not resident) over the status channel."""
        chain, tasks = store.request_profile(rid)
        if not self._distribute_profiles:
            # DAG-oblivious tier: no peer profile ships (replicas keep no
            # DAG view), but the legacy status channel still announces the
            # chain's skeleton blocks so residency replicas stay coherent.
            # Dedup against the shard's OWN replica — bus-delivered state
            # only, so this path survives a real-RPC bus.
            replica = self.trackers[shard].state
            for node in chain:
                bid = self._ns(shard, node.block_id)
                if bid not in replica.materialized:
                    self.trackers[shard].report_status(
                        "materialized_disk", bid)
            return
        job = JobDAG()
        for node in chain:
            job.add_block(BlockMeta(id=self._ns(shard, node.block_id),
                                    size=0, dataset=f"s{shard}:kv",
                                    index=node.uid))
        for i, t in enumerate(tasks):
            job.add_block(BlockMeta(id=self._ns(shard, t.output), size=0,
                                    dataset=f"s{shard}:req", index=i))
            job.add_task(TaskSpec(
                id=self._ns(shard, t.id),
                inputs=tuple(self._ns(shard, b) for b in t.inputs),
                output=self._ns(shard, t.output),
                job=self._ns(shard, t.job)))
        new_blocks, _ = self.master.submit_job(job)
        chain_ids = {self._ns(shard, n.block_id) for n in chain}
        for b in new_blocks:
            if b.id in chain_ids:
                self.trackers[shard].report_status("materialized_disk", b.id)

    # --------------------------------------------------------------- serving
    def shard_of(self, prompt: Sequence[int]) -> int:
        return route_prefix(prompt, self.n_shards, self.block_tokens)

    def submit(self, prompt: Sequence[int], max_new: int = 16, *,
               deadline: Optional[float] = None,
               arrival: Optional[float] = None) -> Tuple[int, Request]:
        k = self.shard_of(prompt)
        eng = self.shards[k]
        req = eng.submit(prompt, max_new=max_new,
                         deadline=deadline, arrival=arrival)
        self._announce(k, eng.store, req.prefix_rid)
        return k, req

    def cancel(self, req: Request) -> bool:
        """Cancel a request on whichever shard owns it (same prefix-affinity
        routing as submit)."""
        return self.shards[self.shard_of(req.prompt)].cancel(req)

    def step(self) -> List[Request]:
        if self.faults is not None:
            self._check_faults()
        finished: List[Request] = []
        for eng in self.shards:
            if eng.queue or any(s is not None for s in eng.slots):
                finished.extend(eng.step())
        return finished

    def run(self, max_steps: int = 100_000) -> None:
        """Round-robin the shards until every queue and slot drains."""
        for _ in range(max_steps):
            if not any(e.queue or any(s is not None for s in e.slots)
                       for e in self.shards):
                return
            self.step()

    # -------------------------------------------------------- fault handling
    def _check_faults(self) -> None:
        """Fire every scheduled shard crash whose shard clock has been
        reached (once each), then deliver any fault-delayed bus messages
        now due on the tier's most advanced clock."""
        fi = self.faults
        for i, (t, k) in enumerate(fi.plan.shard_crashes):
            if (0 <= k < self.n_shards and self.shards[k].now >= t
                    and fi.claim(("shard", i))):
                self._crash_shard(k)
        if self.bus._delayed:
            self.bus.flush_delayed(max(e.now for e in self.shards))

    def _crash_shard(self, k: int) -> None:
        """Kill shard ``k`` and fail over: its device/host/disk KV state is
        gone, so (1) its whole DAG namespace is purged from the
        coordination plane (the master relays, so every surviving replica
        converges), (2) a replacement engine + store + ``PeerTracker``
        replica is built on the same bus endpoint and seeded via the
        anti-entropy ``resync`` protocol, and (3) every in-flight request
        is re-registered and requeued on the fresh shard with capped
        exponential backoff — deadlines unchanged, so the lost work counts
        against goodput exactly as a client would experience it."""
        fi = self.faults
        fi.count("fault.shard_crash")
        self.shard_crashes_fired += 1
        old = self.shards[k]
        store = old.store
        if old.trace is not None:
            old.trace.vt = old.now
            old.trace.instant(
                "fault.shard_crash", "engine", k, _TID_ENGINE,
                args={"shard": k,
                      "in_flight": sum(s is not None for s in old.slots),
                      "queued": len(old.queue)})
        inflight = sorted(
            (r for r in list(old.slots) + list(old.queue)
             if r is not None and not r.done),
            key=lambda r: r.rid)
        # ---- purge the namespace from the global coordination state.
        # Driver-originated status updates relay to every replica, so the
        # surviving shards and the master converge on "shard k holds
        # nothing" before the replacement announces anything.
        if self._distribute_profiles:
            for rid in sorted(store._req_tasks):
                for tid in store._req_tasks[rid]:
                    ns = self._ns(k, tid)
                    if ns in self.master.dag.tasks:
                        self.master.status_update("task_removed", ns)
        for node in sorted(store._nodes.values(), key=lambda n: n.uid):
            bid = self._ns(k, node.block_id)
            if bid in self.master.state.cached:
                self.master.status_update("evicted", bid)
            self.master.status_update("forget_block", bid)
        old.close()
        # ---- replacement replica on the same bus endpoint (re-register
        # swaps the handler) + fresh store/engine with the old clock and a
        # request-id counter past the old one (rids stay unique per pid)
        tracker = PeerTracker(k, self.bus)
        tracker.record_eviction_log = self.trackers[k].record_eviction_log
        self.trackers[k] = tracker
        new_store = self._build_store(k)
        self._wire(k, new_store)
        eng = self._build_engine(new_store)
        eng.now = old.now
        eng._rid = itertools.count(next(old._rid))
        if self._recorder is not None:
            eng.attach_trace(self._recorder, pid=k, name=f"shard{k}")
        self.shards[k] = eng
        tracker.request_resync(include_dag=self._distribute_profiles)
        fi.count("recover.resync")
        if eng.trace is not None:
            eng.trace.instant(
                "recover.resync", "engine", k, _TID_ENGINE,
                args={"shard": k, "include_dag": self._distribute_profiles})
        # ---- requeue in-flight work, REUSING the Request objects (the
        # caller holds references): generation restarts from scratch on
        # the rebuilt shard after a capped exponential backoff
        for r in inflight:
            r.slot = -1
            r.pos = 0
            r.generated = []
            r.n_generated = 0
            r._lazy_out = []
            r.prefill_skipped = 0
            r.first_token_at = None
            r.retries += 1
            r.not_before = eng.now + fi.plan.backoff(r.retries)
            r.prefix_rid = eng.store.register_request(r.prompt)
            eng.queue.append(r)
            self._announce(k, eng.store, r.prefix_rid)
            self.failover_retries += 1
            fi.count("recover.requeue")
            if eng.trace is not None:
                eng.trace.instant(
                    "recover.requeue", "engine", k, _TID_ENGINE,
                    args={"rid": r.rid, "retries": r.retries,
                          "not_before": r.not_before})

    def resync_replicas(self) -> None:
        """Anti-entropy sweep: every tracker pulls the master's snapshot.
        Reconverges replicas that drifted behind dropped status traffic
        (crash rebuilds resync automatically)."""
        for tr in self.trackers:
            tr.request_resync(include_dag=self._distribute_profiles)

    def close(self) -> None:
        """Deterministic teardown of every shard's file-backed resources."""
        for eng in self.shards:
            eng.close()

    # ------------------------------------------------------------ invariants
    def verify_replicas(self) -> None:
        """Every tracker's replica must agree with every shard's own store
        state (the authority for its namespace): residency, reference
        counts, effective reference counts. Proves the bus carried the
        whole truth — the sharded tier's analogue of the sim's
        ``ClusterSim.verify_replicas``."""
        for k, eng in enumerate(self.shards):
            st = eng.store.state
            resident = {self._ns(k, b) for b in st.cached}
            pfx = f"s{k}:"
            for tr in self.trackers + [self.master]:
                rs = tr.state
                assert {b for b in rs.cached
                        if b.startswith(pfx)} == resident, \
                    f"{getattr(tr, 'name', 'master')}: shard {k} residency"
                if not self._distribute_profiles:
                    continue   # no peer profile -> replica has no DAG view
                for bid in eng.store._nodes:
                    nb = self._ns(k, bid)
                    assert rs.ref_count.get(nb, 0) == \
                        st.ref_count.get(bid, 0), f"ref[{nb}]"
                    assert rs.eff_ref_count.get(nb, 0) == \
                        st.eff_ref_count.get(bid, 0), f"eff[{nb}]"

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        cache = CacheMetrics()
        for eng in self.shards:
            cache = cache.merge(eng.store.metrics_obj)
        cache.check_attribution()
        out = cache.as_dict()
        out["used_bytes"] = sum(e.store.used for e in self.shards)
        out["host_used_bytes"] = sum(getattr(e.store, "host_used", 0)
                                     for e in self.shards)
        # tier utilization, aggregated across shards (high-water sums are
        # an upper bound on simultaneous use but exact per shard)
        for key, get in (("pool_blocks", lambda e: e.pool.num_blocks),
                         ("pool_blocks_in_use",
                          lambda e: e.pool.blocks_in_use),
                         ("pool_high_water", lambda e: e.pool.high_water)):
            out[key] = sum(get(e) for e in self.shards)
        host_pools = [e.store.host_pool for e in self.shards
                      if getattr(e.store, "host_pool", None) is not None]
        if host_pools:
            out["host_blocks"] = sum(p.num_blocks for p in host_pools)
            out["host_blocks_in_use"] = sum(p.blocks_in_use
                                            for p in host_pools)
            out["host_high_water"] = sum(p.high_water for p in host_pools)
        disk_pools = [e.store.disk_pool for e in self.shards
                      if getattr(e.store, "disk_pool", None) is not None]
        if disk_pools:
            out["disk_used_bytes"] = sum(getattr(e.store, "disk_used", 0)
                                         for e in self.shards)
            out["disk_blocks"] = sum(p.num_blocks for p in disk_pools)
            out["disk_blocks_in_use"] = sum(p.blocks_in_use
                                            for p in disk_pools)
            out["disk_high_water"] = sum(p.high_water for p in disk_pools)
        for field in ("steps", "prefill_tokens", "prefill_tokens_skipped",
                      "decoded_tokens", "rejected", "cancellations"):
            out[field if field != "steps" else "engine_steps"] = \
                sum(getattr(e, field) for e in self.shards)
        out["prefill_saved_frac"] = (
            out["prefill_tokens_skipped"]
            / max(out["prefill_tokens"] + out["prefill_tokens_skipped"], 1))
        out["n_shards"] = self.n_shards
        out["shard_crashes"] = self.shard_crashes_fired
        out["failover_retries"] = self.failover_retries
        for key, val in self.bus.stats.as_dict().items():
            out[f"msg_{key}"] = val
        return out

"""Deadline-aware step scheduling for the serve front door (PR 6).

The engine's step loop asks a ``Scheduler`` two questions:

* **admission** — when a slot frees, *which* queued request takes it
  (``admit_idx``): FIFO for the baseline schedulers, earliest-deadline-
  first for the budgeted one;
* **prefill planning** — how many prompt tokens each prefilling slot may
  feed *this step* (``plan_prefill``). Decode slots are always packed
  first by the engine (one token each, pipelined feeds); the scheduler
  only divides the step's *prefill* work.

Three policies:

* ``fcfs`` — every prefilling slot feeds its full chunk every step. This
  is exactly the pre-scheduler engine behavior (and is the default), so a
  scheduled engine degrades bit-identically to the old ``run()`` loop —
  ``tests/test_engine_equivalence.py`` proves it.
* ``decode-first`` — prefill runs only on steps with no decode work:
  TPOT is never taxed by prefill, TTFT starves behind long decodes. One
  extreme of the tradeoff the budgeted scheduler navigates.
* ``budgeted`` — each step spends at most ``prefill_budget`` prompt
  tokens, allocated earliest-deadline-first across prefilling slots
  (ties: arrival order). A long prefill is *preempted* — fed zero tokens
  — whenever more urgent prompts exhaust the budget, so a new arrival's
  TTFT and the decode slots' TPOT are both bounded by
  ``base + per_token * (budget + decode_slots)`` per step instead of
  ``per_token * (slots * chunk)``.

Because greedy decoding with KV-exact prefix restore makes a request's
tokens independent of *when* its chunks are scheduled, all three policies
produce token-identical generations — scheduling moves latency, never
text. Eviction logs may legitimately differ (store ops reorder).

Time is **virtual**: the engine advances its clock by ``StepCostModel``
per step (affine in the tokens dispatched), so scheduled runs, TTFT/TPOT
percentiles, and goodput are deterministic under a seeded arrival trace —
on CI CPU as on a TPU pod. ``play_trace`` is the front-door event loop
that drives an engine (or a ``ShardedFrontend``, per-shard queues) from a
timed arrival trace with admission control and backpressure.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..obs.trace import TID_SCHED as _TID_SCHED


def _trace_retry(eng, tries: int, wait: float) -> None:
    """Mark a QueueFull-bounced arrival's re-offer on the trace, so
    ``trace_report`` can split retried bounces from final rejections
    (the engine's own ``rejected`` instant fires for both)."""
    rec = getattr(eng, "trace", None)
    if rec is not None:
        rec.instant("sched.retry", "sched", eng._trace_pid, _TID_SCHED,
                    args={"tries": tries, "wait": wait})


class QueueFull(RuntimeError):
    """Backpressure: the engine's admission queue is at ``max_queue``.

    Carries actionable hints for the client: ``depth`` (how deep the
    queue it bounced off is) and ``retry_after`` (the engine's
    ``StepCostModel`` estimate of virtual-clock time until a slot —
    and hence a queue position — frees)."""

    def __init__(self, msg: str = "", depth: Optional[int] = None,
                 retry_after: Optional[float] = None) -> None:
        super().__init__(msg)
        self.depth = depth
        self.retry_after = retry_after


@dataclass(frozen=True)
class StepCostModel:
    """Virtual wall-clock of one engine step: fixed dispatch/host overhead
    (``base``), per-token MLP/projection FLOPs (``per_token``), and — when
    ``per_attn`` is nonzero — the attention term, linear in KV *pairs*
    read this step (Σ over slots of tokens_fed × context_length). The
    attention term is what makes a long prompt's late prefill chunks
    disproportionately expensive, and therefore what a deadline-aware
    scheduler can keep off the steps interactive requests share (the
    stall-free-batching observation). Units are abstract milliseconds;
    the *ratios* between schedulers, not the absolute numbers, are the
    measurement."""
    base: float = 0.25
    per_token: float = 0.05
    per_attn: float = 0.0

    def __call__(self, prefill_tokens: int, decode_tokens: int,
                 attn_pairs: int = 0) -> float:
        return (self.base
                + self.per_token * (prefill_tokens + decode_tokens)
                + self.per_attn * attn_pairs)


def _deadline_key(r):
    """EDF order: requests with deadlines first (earliest first), then
    arrival order; rid breaks exact ties deterministically."""
    return (r.deadline is None,
            r.deadline if r.deadline is not None else 0.0,
            r.arrival, r.rid)


class Scheduler:
    """Base policy = FCFS admission + full-chunk prefill for everyone."""

    name = "fcfs"

    def admit_idx(self, queue: Sequence) -> int:
        """Index into ``queue`` of the request that takes the free slot."""
        return 0

    def plan_prefill(self, prefilling: List, chunk: int, n_decode: int
                     ) -> Dict[int, int]:
        """slot -> prompt tokens to feed this step (omitted slots idle).
        ``prefilling`` holds the active prefill-phase requests in slot
        order; the engine has already packed ``n_decode`` decode slots
        (one token each) into the same dispatch."""
        return {r.slot: min(chunk, len(r.prompt) - r.pos)
                for r in prefilling}


class FCFSScheduler(Scheduler):
    pass


class DecodeFirstScheduler(Scheduler):
    """Strict decode priority: prefill only on steps with no decode
    work — TPOT is never taxed by prefill, TTFT starves behind decodes."""

    name = "decode-first"

    def plan_prefill(self, prefilling, chunk, n_decode):
        if n_decode > 0:
            return {}
        return super().plan_prefill(prefilling, chunk, n_decode)


class BudgetedScheduler(Scheduler):
    """Deadline-aware prefill budgeting: decode packs first, then up to
    ``prefill_budget`` prompt tokens are spent earliest-deadline-first
    across prefilling slots; slots past the budget are preempted (fed 0).
    ``prefill_budget=None`` removes the cap (degrades to FCFS planning);
    ``prefill_budget=0`` degrades to strict decode-first.

    When the engine's ``StepCostModel`` has a nonzero attention term, a
    chunk is charged its *cost-equivalent* tokens — ``n`` tokens at
    context position ``p`` cost like ``n * (1 + (per_attn/per_token) *
    (p+n))`` flat ones — so the late, expensive chunks of a long prompt
    automatically shrink to fit the budget. That bounds every step at
    ``~base + per_token*(budget + decodes)`` regardless of how deep into
    a long context a slot is, which is the whole point: TPOT and new
    arrivals' TTFT never inherit a long prefill's attention bill. (The
    engine wires its own clock in when the scheduler doesn't carry one.)"""

    name = "budgeted"

    def __init__(self, prefill_budget: Optional[int] = None,
                 clock: Optional[StepCostModel] = None) -> None:
        self.prefill_budget = prefill_budget
        self.clock = clock

    def admit_idx(self, queue):
        best, best_key = 0, None
        for i, r in enumerate(queue):
            k = _deadline_key(r)
            if best_key is None or k < best_key:
                best, best_key = i, k
        return best

    def _eff_tokens(self, n: int, pos: int) -> int:
        """Cost-equivalent flat tokens of an ``n``-token chunk whose
        context ends at ``pos + n``."""
        c = self.clock
        if n <= 0 or c is None or not c.per_attn or not c.per_token:
            return n
        return n + int(round(c.per_attn * n * (pos + n) / c.per_token))

    def plan_prefill(self, prefilling, chunk, n_decode):
        if self.prefill_budget is None:
            return super().plan_prefill(prefilling, chunk, n_decode)
        left = self.prefill_budget
        plan: Dict[int, int] = {}
        for r in sorted(prefilling, key=_deadline_key):
            if left <= 0:
                break
            n = min(chunk, len(r.prompt) - r.pos)
            while n > 0 and self._eff_tokens(n, r.pos) > left:
                n -= 1
            if n > 0:
                plan[r.slot] = n
                left -= self._eff_tokens(n, r.pos)
        return plan


_SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "decode-first": DecodeFirstScheduler,
    "budgeted": BudgetedScheduler,
}


def make_scheduler(name: str, *, prefill_budget: Optional[int] = None
                   ) -> Scheduler:
    if name not in _SCHEDULERS:
        raise ValueError(f"unknown scheduler {name!r}; "
                         f"have {sorted(_SCHEDULERS)}")
    if name == "budgeted":
        return BudgetedScheduler(prefill_budget)
    return _SCHEDULERS[name]()


# ---------------------------------------------------------------------------
# Front-door event loop: timed arrivals -> submit/step/backpressure
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TracedRequest:
    """One arrival of a timed trace. ``deadline`` is the *relative* TTFT
    SLO (first token due by ``t + deadline`` on the virtual clock);
    ``None`` means best-effort."""
    t: float
    prompt: Sequence[int]
    max_new: int = 16
    deadline: Optional[float] = None


@dataclass
class TraceReport:
    requests: List = field(default_factory=list)   # admitted Requests
    rejected: int = 0                              # shed by backpressure
    retried: int = 0                               # rejected, then re-offered

    def merge(self, other: "TraceReport") -> "TraceReport":
        return TraceReport(self.requests + other.requests,
                           self.rejected + other.rejected,
                           self.retried + other.retried)


def _engine_idle(eng) -> bool:
    return not eng.queue and all(s is None for s in eng.slots)


def _play_engine(front, eng, trace: List[TracedRequest],
                 max_steps: int, retry_rejected: int = 0) -> TraceReport:
    """Drive one engine from a time-sorted trace: submit every arrival the
    virtual clock has reached (rejections count, not raise), advance the
    clock over idle gaps, step while there is work. ``front`` is what
    ``submit`` is called on (the engine itself, or a ShardedFrontend that
    routes + announces and lands the request on ``eng``).

    ``retry_rejected`` > 0 re-offers each ``QueueFull``-bounced arrival up
    to that many times, waiting out the rejection's ``retry_after`` hint;
    retries keep the original arrival time, so the wait shows up in TTFT
    and counts against goodput."""
    report = TraceReport()
    pending = [(tr.t, i, 0, tr) for i, tr in enumerate(trace)]
    heapq.heapify(pending)
    seq = itertools.count(len(trace))
    for _ in range(max_steps):
        while pending and pending[0][0] <= eng.now:
            _, _, tries, tr = heapq.heappop(pending)
            abs_deadline = None if tr.deadline is None else tr.t + tr.deadline
            try:
                req = front.submit(tr.prompt, max_new=tr.max_new,
                                   deadline=abs_deadline, arrival=tr.t)
            except QueueFull as e:
                if tries < retry_rejected:
                    wait = e.retry_after if e.retry_after else 1.0
                    heapq.heappush(pending, (eng.now + wait, next(seq),
                                             tries + 1, tr))
                    report.retried += 1
                    _trace_retry(eng, tries + 1, wait)
                else:
                    report.rejected += 1
                continue
            if isinstance(req, tuple):          # ShardedFrontend returns
                req = req[1]                    # (shard, Request)
            report.requests.append(req)
        if _engine_idle(eng):
            if not pending:
                return report
            eng.now = max(eng.now, pending[0][0])  # jump the idle gap
            continue
        eng.step()
    raise RuntimeError(f"trace not drained in {max_steps} steps")


def _play_frontend(front, trace: List[TracedRequest], max_steps: int,
                   retry_rejected: int = 0) -> TraceReport:
    """Interleaved front-door loop for a fault-injected ``ShardedFrontend``:
    all shards step round-robin through ``front.step()`` (where crash
    detection and failover live), and each arrival is submitted once its
    own shard's clock reaches it. The per-shard sequential replay in
    ``play_trace`` cannot drive crash recovery — a crashed shard's
    requeued requests must interleave with the other shards' progress."""
    report = TraceReport()
    pending = [(tr.t, i, 0, tr) for i, tr in enumerate(trace)]
    heapq.heapify(pending)
    seq = itertools.count(len(trace))
    for _ in range(max_steps):
        while pending:
            t, _, tries, tr = pending[0]
            eng = front.shards[front.shard_of(tr.prompt)]
            if t > eng.now:
                break
            heapq.heappop(pending)
            abs_deadline = None if tr.deadline is None else tr.t + tr.deadline
            try:
                _, req = front.submit(tr.prompt, max_new=tr.max_new,
                                      deadline=abs_deadline, arrival=tr.t)
            except QueueFull as e:
                if tries < retry_rejected:
                    wait = e.retry_after if e.retry_after else 1.0
                    heapq.heappush(pending, (eng.now + wait, next(seq),
                                             tries + 1, tr))
                    report.retried += 1
                    _trace_retry(eng, tries + 1, wait)
                else:
                    report.rejected += 1
                continue
            report.requests.append(req)
        if not any(e.queue or any(s is not None for s in e.slots)
                   for e in front.shards):
            if not pending:
                return report
            t = pending[0][0]
            for e in front.shards:
                e.now = max(e.now, t)           # jump the idle gap
            continue
        front.step()
    raise RuntimeError(f"trace not drained in {max_steps} steps")


def play_trace(engine, trace: Sequence[TracedRequest], *,
               max_steps: int = 1_000_000,
               retry_rejected: int = 0) -> TraceReport:
    """Run a timed arrival trace through a ``ServeEngine`` or a
    ``ShardedFrontend``. Shards are independent servers with independent
    virtual clocks, so a frontend trace is split by the (unchanged)
    prefix-affinity router and each shard replays its own arrivals —
    per-shard queues, per-shard backpressure. A fault-injected frontend
    instead runs the interleaved loop (shard crashes re-route work across
    shards mid-trace, so the shards cannot replay independently)."""
    trace = sorted(trace, key=lambda r: r.t)
    if hasattr(engine, "shards"):               # ShardedFrontend
        faults = getattr(engine, "faults", None)
        if faults is not None and not faults.plan.empty:
            # an empty plan injects nothing, so the (bit-identical)
            # per-shard replay below serves it too
            return _play_frontend(engine, trace, max_steps, retry_rejected)
        per_shard: Dict[int, List[TracedRequest]] = {}
        for tr in trace:
            per_shard.setdefault(engine.shard_of(tr.prompt), []).append(tr)
        report = TraceReport()
        for k, shard_trace in sorted(per_shard.items()):
            report = report.merge(
                _play_engine(engine, engine.shards[k], shard_trace,
                             max_steps, retry_rejected))
        return report
    return _play_engine(engine, engine, trace, max_steps, retry_rejected)


# ---------------------------------------------------------------------------
# Latency accounting
# ---------------------------------------------------------------------------


def _pct(xs: List[float], q: float) -> float:
    # empty sample -> 0.0, not NaN: a trace where nothing finished must
    # still produce a numeric (JSON-safe, comparable) report
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def latency_stats(report: TraceReport) -> Dict[str, float]:
    """TTFT/TPOT percentiles and goodput-under-deadline for a finished
    trace. TTFT = first decode token computed minus arrival; TPOT = mean
    inter-token time over a request's decode phase. Goodput counts a
    request iff it was admitted, not cancelled, and its first token
    landed by its deadline (no-deadline requests count when they
    complete); rejected arrivals count against the denominator. NaN-free
    by construction: an empty or zero-offered trace reports zeros."""
    ttft = [r.first_token_at - r.arrival for r in report.requests
            if r.first_token_at is not None]
    tpot = [(r.finished_at - r.first_token_at) / (len(r.generated) - 1)
            for r in report.requests
            if r.finished_at is not None and r.first_token_at is not None
            and len(r.generated) > 1]
    met = 0
    for r in report.requests:
        if r.cancelled or r.first_token_at is None:
            continue
        if r.deadline is None:
            met += r.finished_at is not None
        else:
            met += r.first_token_at <= r.deadline
    offered = len(report.requests) + report.rejected
    out = {"n_offered": offered, "n_rejected": report.rejected,
           "n_retried": getattr(report, "retried", 0),
           "goodput": round(float(met) / max(offered, 1), 4)}
    for name, xs in (("ttft", ttft), ("tpot", tpot)):
        for q in (50, 95, 99):
            out[f"{name}_p{q}"] = round(_pct(xs, q), 4)
    return out

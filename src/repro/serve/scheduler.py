"""Deadline-aware step scheduling for the serve front door (PR 6).

The engine's step loop asks a ``Scheduler`` two questions:

* **admission** — when a slot frees, *which* queued request takes it
  (``admit_idx``): FIFO for the baseline schedulers, earliest-deadline-
  first for the budgeted one;
* **prefill planning** — how many prompt tokens each prefilling slot may
  feed *this step* (``plan_prefill``). Decode slots are always packed
  first by the engine (one token each, pipelined feeds); the scheduler
  only divides the step's *prefill* work.

Three policies:

* ``fcfs`` — every prefilling slot feeds its full chunk every step. This
  is exactly the pre-scheduler engine behavior (and is the default), so a
  scheduled engine degrades bit-identically to the old ``run()`` loop —
  ``tests/test_engine_equivalence.py`` proves it.
* ``decode-first`` — prefill runs only on steps with no decode work:
  TPOT is never taxed by prefill, TTFT starves behind long decodes. One
  extreme of the tradeoff the budgeted scheduler navigates.
* ``budgeted`` — each step spends at most ``prefill_budget`` prompt
  tokens, allocated earliest-deadline-first across prefilling slots
  (ties: arrival order). A long prefill is *preempted* — fed zero tokens
  — whenever more urgent prompts exhaust the budget, so a new arrival's
  TTFT and the decode slots' TPOT are both bounded by
  ``base + per_token * (budget + decode_slots)`` per step instead of
  ``per_token * (slots * chunk)``.

Because greedy decoding with KV-exact prefix restore makes a request's
tokens independent of *when* its chunks are scheduled, all three policies
produce token-identical generations — scheduling moves latency, never
text. Eviction logs may legitimately differ (store ops reorder).

Time is **virtual**: the engine advances its clock by ``StepCostModel``
per step (affine in the tokens dispatched), so scheduled runs, TTFT/TPOT
percentiles, and goodput are deterministic under a seeded arrival trace —
on CI CPU as on a TPU pod. ``play_trace`` is the front-door event loop
that drives an engine (or a ``ShardedFrontend``, per-shard queues) from a
timed arrival trace with admission control and backpressure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


class QueueFull(RuntimeError):
    """Backpressure: the engine's admission queue is at ``max_queue``."""


@dataclass(frozen=True)
class StepCostModel:
    """Virtual wall-clock of one engine step: fixed dispatch/host overhead
    (``base``), per-token MLP/projection FLOPs (``per_token``), and — when
    ``per_attn`` is nonzero — the attention term, linear in KV *pairs*
    read this step (Σ over slots of tokens_fed × context_length). The
    attention term is what makes a long prompt's late prefill chunks
    disproportionately expensive, and therefore what a deadline-aware
    scheduler can keep off the steps interactive requests share (the
    stall-free-batching observation). Units are abstract milliseconds;
    the *ratios* between schedulers, not the absolute numbers, are the
    measurement."""
    base: float = 0.25
    per_token: float = 0.05
    per_attn: float = 0.0

    def __call__(self, prefill_tokens: int, decode_tokens: int,
                 attn_pairs: int = 0) -> float:
        return (self.base
                + self.per_token * (prefill_tokens + decode_tokens)
                + self.per_attn * attn_pairs)


def _deadline_key(r):
    """EDF order: requests with deadlines first (earliest first), then
    arrival order; rid breaks exact ties deterministically."""
    return (r.deadline is None,
            r.deadline if r.deadline is not None else 0.0,
            r.arrival, r.rid)


class Scheduler:
    """Base policy = FCFS admission + full-chunk prefill for everyone."""

    name = "fcfs"

    def admit_idx(self, queue: Sequence) -> int:
        """Index into ``queue`` of the request that takes the free slot."""
        return 0

    def plan_prefill(self, prefilling: List, chunk: int, n_decode: int
                     ) -> Dict[int, int]:
        """slot -> prompt tokens to feed this step (omitted slots idle).
        ``prefilling`` holds the active prefill-phase requests in slot
        order; the engine has already packed ``n_decode`` decode slots
        (one token each) into the same dispatch."""
        return {r.slot: min(chunk, len(r.prompt) - r.pos)
                for r in prefilling}


class FCFSScheduler(Scheduler):
    pass


class DecodeFirstScheduler(Scheduler):
    """Strict decode priority: prefill only on steps with no decode
    work — TPOT is never taxed by prefill, TTFT starves behind decodes."""

    name = "decode-first"

    def plan_prefill(self, prefilling, chunk, n_decode):
        if n_decode > 0:
            return {}
        return super().plan_prefill(prefilling, chunk, n_decode)


class BudgetedScheduler(Scheduler):
    """Deadline-aware prefill budgeting: decode packs first, then up to
    ``prefill_budget`` prompt tokens are spent earliest-deadline-first
    across prefilling slots; slots past the budget are preempted (fed 0).
    ``prefill_budget=None`` removes the cap (degrades to FCFS planning);
    ``prefill_budget=0`` degrades to strict decode-first.

    When the engine's ``StepCostModel`` has a nonzero attention term, a
    chunk is charged its *cost-equivalent* tokens — ``n`` tokens at
    context position ``p`` cost like ``n * (1 + (per_attn/per_token) *
    (p+n))`` flat ones — so the late, expensive chunks of a long prompt
    automatically shrink to fit the budget. That bounds every step at
    ``~base + per_token*(budget + decodes)`` regardless of how deep into
    a long context a slot is, which is the whole point: TPOT and new
    arrivals' TTFT never inherit a long prefill's attention bill. (The
    engine wires its own clock in when the scheduler doesn't carry one.)"""

    name = "budgeted"

    def __init__(self, prefill_budget: Optional[int] = None,
                 clock: Optional[StepCostModel] = None) -> None:
        self.prefill_budget = prefill_budget
        self.clock = clock

    def admit_idx(self, queue):
        best, best_key = 0, None
        for i, r in enumerate(queue):
            k = _deadline_key(r)
            if best_key is None or k < best_key:
                best, best_key = i, k
        return best

    def _eff_tokens(self, n: int, pos: int) -> int:
        """Cost-equivalent flat tokens of an ``n``-token chunk whose
        context ends at ``pos + n``."""
        c = self.clock
        if n <= 0 or c is None or not c.per_attn or not c.per_token:
            return n
        return n + int(round(c.per_attn * n * (pos + n) / c.per_token))

    def plan_prefill(self, prefilling, chunk, n_decode):
        if self.prefill_budget is None:
            return super().plan_prefill(prefilling, chunk, n_decode)
        left = self.prefill_budget
        plan: Dict[int, int] = {}
        for r in sorted(prefilling, key=_deadline_key):
            if left <= 0:
                break
            n = min(chunk, len(r.prompt) - r.pos)
            while n > 0 and self._eff_tokens(n, r.pos) > left:
                n -= 1
            if n > 0:
                plan[r.slot] = n
                left -= self._eff_tokens(n, r.pos)
        return plan


_SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "decode-first": DecodeFirstScheduler,
    "budgeted": BudgetedScheduler,
}


def make_scheduler(name: str, *, prefill_budget: Optional[int] = None
                   ) -> Scheduler:
    if name not in _SCHEDULERS:
        raise ValueError(f"unknown scheduler {name!r}; "
                         f"have {sorted(_SCHEDULERS)}")
    if name == "budgeted":
        return BudgetedScheduler(prefill_budget)
    return _SCHEDULERS[name]()


# ---------------------------------------------------------------------------
# Front-door event loop: timed arrivals -> submit/step/backpressure
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TracedRequest:
    """One arrival of a timed trace. ``deadline`` is the *relative* TTFT
    SLO (first token due by ``t + deadline`` on the virtual clock);
    ``None`` means best-effort."""
    t: float
    prompt: Sequence[int]
    max_new: int = 16
    deadline: Optional[float] = None


@dataclass
class TraceReport:
    requests: List = field(default_factory=list)   # admitted Requests
    rejected: int = 0                              # shed by backpressure

    def merge(self, other: "TraceReport") -> "TraceReport":
        return TraceReport(self.requests + other.requests,
                           self.rejected + other.rejected)


def _engine_idle(eng) -> bool:
    return not eng.queue and all(s is None for s in eng.slots)


def _play_engine(front, eng, trace: List[TracedRequest],
                 max_steps: int) -> TraceReport:
    """Drive one engine from a time-sorted trace: submit every arrival the
    virtual clock has reached (rejections count, not raise), advance the
    clock over idle gaps, step while there is work. ``front`` is what
    ``submit`` is called on (the engine itself, or a ShardedFrontend that
    routes + announces and lands the request on ``eng``)."""
    report = TraceReport()
    i = 0
    for _ in range(max_steps):
        while i < len(trace) and trace[i].t <= eng.now:
            tr = trace[i]
            i += 1
            abs_deadline = None if tr.deadline is None else tr.t + tr.deadline
            try:
                req = front.submit(tr.prompt, max_new=tr.max_new,
                                   deadline=abs_deadline, arrival=tr.t)
            except QueueFull:
                report.rejected += 1
                continue
            if isinstance(req, tuple):          # ShardedFrontend returns
                req = req[1]                    # (shard, Request)
            report.requests.append(req)
        if _engine_idle(eng):
            if i >= len(trace):
                return report
            eng.now = max(eng.now, trace[i].t)  # jump the idle gap
            continue
        eng.step()
    raise RuntimeError(f"trace not drained in {max_steps} steps")


def play_trace(engine, trace: Sequence[TracedRequest], *,
               max_steps: int = 1_000_000) -> TraceReport:
    """Run a timed arrival trace through a ``ServeEngine`` or a
    ``ShardedFrontend``. Shards are independent servers with independent
    virtual clocks, so a frontend trace is split by the (unchanged)
    prefix-affinity router and each shard replays its own arrivals —
    per-shard queues, per-shard backpressure."""
    trace = sorted(trace, key=lambda r: r.t)
    if hasattr(engine, "shards"):               # ShardedFrontend
        per_shard: Dict[int, List[TracedRequest]] = {}
        for tr in trace:
            per_shard.setdefault(engine.shard_of(tr.prompt), []).append(tr)
        report = TraceReport()
        for k, shard_trace in sorted(per_shard.items()):
            report = report.merge(_play_engine(engine, engine.shards[k],
                                               shard_trace, max_steps))
        return report
    return _play_engine(engine, engine, trace, max_steps)


# ---------------------------------------------------------------------------
# Latency accounting
# ---------------------------------------------------------------------------


def _pct(xs: List[float], q: float) -> float:
    # empty sample -> 0.0, not NaN: a trace where nothing finished must
    # still produce a numeric (JSON-safe, comparable) report
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def latency_stats(report: TraceReport) -> Dict[str, float]:
    """TTFT/TPOT percentiles and goodput-under-deadline for a finished
    trace. TTFT = first decode token computed minus arrival; TPOT = mean
    inter-token time over a request's decode phase. Goodput counts a
    request iff it was admitted, not cancelled, and its first token
    landed by its deadline (no-deadline requests count when they
    complete); rejected arrivals count against the denominator. NaN-free
    by construction: an empty or zero-offered trace reports zeros."""
    ttft = [r.first_token_at - r.arrival for r in report.requests
            if r.first_token_at is not None]
    tpot = [(r.finished_at - r.first_token_at) / (len(r.generated) - 1)
            for r in report.requests
            if r.finished_at is not None and r.first_token_at is not None
            and len(r.generated) > 1]
    met = 0
    for r in report.requests:
        if r.cancelled or r.first_token_at is None:
            continue
        if r.deadline is None:
            met += r.finished_at is not None
        else:
            met += r.first_token_at <= r.deadline
    offered = len(report.requests) + report.rejected
    out = {"n_offered": offered, "n_rejected": report.rejected,
           "goodput": round(float(met) / max(offered, 1), 4)}
    for name, xs in (("ttft", ttft), ("tpot", tpot)):
        for q in (50, 95, 99):
            out[f"{name}_p{q}"] = round(_pct(xs, q), 4)
    return out

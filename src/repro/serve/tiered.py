"""Tiered KV store: LERC-aware demotion down a compressed storage ladder.

``core`` honors the paper's all-or-nothing property with a two-tier
MemoryTier/DiskTier store: eviction moves a block to the slow tier, and a
task only speeds up when *every* peer sits in the fast tier. This module
gives the serving data plane the same shape, now three rungs deep. Tier 0
is the device-resident ``KVBlockPool``; tier 1 is a preallocated
``HostBlockPool``; tier 2 (PR 8) is a file-backed ``DiskBlockPool``.
Under device pressure a prefix-cache block *demotes* — one jitted
device→host row copy — instead of dying; under host pressure it demotes
*again* to disk; and a later lookup that walks over demoted blocks
promotes the usable chain back to the device pool, paying a copy (and a
dequantize) instead of a prefill recompute.

**Demotion transcodes** (PR 8): with ``kv_quant`` set, the device→host
copy quantizes rows on device (``repro.quant`` per-layer-per-block
scales) so the host budget holds ~``itemsize``-ratio more blocks — the
paper's lever is complete chains per byte, and narrowing the dtype is the
cheapest way to buy more of them. The host→disk hop can narrow again
(``disk_quant``); promotion dequantizes inside the device scatter jit.
With ``kv_quant`` "none" every path is the lossless copy it was in PR 4,
bit-identical to the pre-PR engine.

Placement policy is the paper's machinery three times over:

* **Demotion victims** are chosen by the store's existing
  ``Policy``/``EvictionIndex`` over the shared ``DagState`` counters — so
  LERC demotes members of broken peer groups (ERC 0) first and keeps
  complete chains wholly on-device. An *effective* hit remains
  tier-0-only: a partially demoted chain is "incomplete" in the paper's
  sense and pays the max-over-blocks promotion copy before it is usable —
  the all-or-nothing bottleneck, now one tier down.
* **Host-tier eviction** runs a second policy-driven ``EvictionIndex``
  over the same counters; its victims demote to disk when a disk tier is
  configured, and die otherwise. A demoted block is never in
  ``DagState.cached``, so every peer group through it is incomplete and a
  completeness-aware key degrades gracefully to (reference count,
  recency) — retention follows who still *references* a chain.
* **Disk-tier eviction** is a THIRD index over the very same counters:
  the final death, back to recomputable-by-prefill. The ladder orders
  blocks by restore cost (table write ≪ host copy ≪ disk page-in ≪
  recompute), and each rung's policy independently keeps the chains
  cheapest to complete at that rung.

Tier-0 state transitions (demotion = eviction from the fast tier) keep
the exact event stream the single-tier store emits: same
``eviction_log``, same ``DagState.on_evicted`` completeness flips, same
``on_evict``/``on_status`` coordination hooks — so a sharded frontend
with tiered shards stays replica-coherent with no protocol changes, and
with the host tier disabled this class is op-for-op a ``PrefixStore``.
Tier 1→2 movement touches no ``DagState`` (the block already left
``cached``), so the slow rungs stay invisible to the coordination plane.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from .. import quant as quantlib
from ..core import EvictionIndex, Policy, make_policy
from ..quant import QuantSpec
from ..obs.trace import TID_STORE as _TID_STORE
from .disk_pool import DiskBlockPool
from .host_pool import HostBlockPool
from .kv_pool import KVBlockPool
from .prefix_store import Node, PrefixStore, blocking_cause


class TieredKVStore(PrefixStore):
    """Three-tier prefix store: device pool (tier 0) + host pool (tier 1)
    + optional disk pool (tier 2), with optional transcoding demotion.

    Construct like a ``PrefixStore`` plus per-tier byte budgets and quant
    formats; the engine attaches the actual pools (it owns the cache
    template) via ``attach_pools``, building them from this store's
    ``quant``/``disk_quant``/``disk_capacity``/``disk_dir`` settings.
    With ``host_capacity_bytes == 0`` (or no pools attached) every code
    path delegates to the base class, bit-identical to a single-tier
    store; with ``kv_quant="none"`` and no disk tier it is bit-identical
    to the PR 4 two-tier store.
    """

    def __init__(self, capacity_bytes: int,
                 policy: Union[str, Policy] = "lerc",
                 block_tokens: int = 16, *,
                 host_capacity_bytes: int = 0,
                 host_policy: Union[str, Policy, None] = None,
                 kv_quant: Union[str, QuantSpec, None] = None,
                 disk_capacity_bytes: int = 0,
                 disk_policy: Union[str, Policy, None] = None,
                 disk_quant: Union[str, QuantSpec, None] = None,
                 disk_dir: Optional[str] = None) -> None:
        super().__init__(capacity_bytes, policy, block_tokens=block_tokens)
        self.host_capacity = host_capacity_bytes
        self.host_used = 0
        if host_policy is None:
            host_policy = make_policy(self.policy.name)
        elif isinstance(host_policy, str):
            host_policy = make_policy(host_policy)
        self.host_policy = host_policy
        self.host_index = EvictionIndex(self.host_policy, self.state)
        # transcode formats: ``quant`` narrows the device→host hop;
        # ``disk_quant`` the host→disk hop (None = inherit the host format,
        # so a lossless host tier gets a lossless disk tier by default)
        self.quant = quantlib.get_spec(kv_quant)
        self.disk_quant = (self.quant if disk_quant is None
                           else quantlib.get_spec(disk_quant))
        self.disk_capacity = disk_capacity_bytes
        self.disk_used = 0
        self.disk_dir = disk_dir
        if disk_policy is None:
            disk_policy = make_policy(self.policy.name)
        elif isinstance(disk_policy, str):
            disk_policy = make_policy(disk_policy)
        self.disk_policy = disk_policy
        self.disk_index = EvictionIndex(self.disk_policy, self.state)
        self.device_pool: Optional[KVBlockPool] = None
        self.host_pool: Optional[HostBlockPool] = None
        self.disk_pool: Optional[DiskBlockPool] = None
        self.host_eviction_log: List[str] = []
        self.disk_eviction_log: List[str] = []
        # demotions batched per ``_make_room`` call: (device row, host row).
        # Victim selection interleaves with per-victim state updates, but
        # the byte movement happens in ONE jitted gather (+ on-device
        # quantize) + device_get at the end of the batch, before any freed
        # device row can be reused.
        self._pending_demotions: List[Tuple[int, int]] = []
        # ---- fault injection + graceful degradation ----
        # repro.faults.FaultInjector shared with the whole run (None =
        # healthy). Must be attached BEFORE attach_pools so the disk pool
        # inherits it.
        self.faults = None
        self.disk_quarantined = False
        # consecutive disk I/O errors; only a successful disk READ resets
        # it — writes landing doesn't prove the bytes come back, so a disk
        # that accepts demotions but fails every promote still quarantines
        self._disk_errors = 0
        # virtual-clock stall accrued by slow promotions this step; the
        # engine drains it into ``now`` after the step's compute charge
        self.pending_stall = 0.0

    # --------------------------------------------------------------- wiring
    def attach_pools(self, device_pool: KVBlockPool,
                     host_pool: HostBlockPool,
                     disk_pool: Optional[DiskBlockPool] = None) -> None:
        self.device_pool = device_pool
        self.host_pool = host_pool
        self.disk_pool = disk_pool
        if disk_pool is not None:
            disk_pool.faults = self.faults
        # fallback/final device evictions still free pool rows directly
        self.evict_payload = device_pool.free

    @property
    def tiered(self) -> bool:
        return (self.host_capacity > 0 and self.host_pool is not None
                and self.host_pool.num_blocks > 0)

    @property
    def disk_tiered(self) -> bool:
        return (self.disk_capacity > 0 and self.disk_pool is not None
                and self.disk_pool.num_blocks > 0
                and not self.disk_quarantined)

    def _host_nbytes(self, node: Node) -> int:
        """Bytes one block charges against the host budget. Quantized
        tiers price the transcoded row (the capacity-per-byte win);
        lossless tiers keep pricing the device byte size — bit-identical
        accounting to the pre-quant store."""
        if self.quant is None:
            return node.nbytes
        return self.host_pool.block_nbytes

    def _trace_move(self, name: str, node: Node, *, src: str,
                    dst: Optional[str], policy: Policy,
                    quant: bool = False) -> None:
        """One tier-transition instant, stamped with the deciding
        policy's eviction key AT decision time (why this victim)."""
        if self.trace is None:
            return
        self.trace.instant(name, "store", self.trace_pid, _TID_STORE, args={
            "uid": node.uid, "block": node.block_id, "src": src, "dst": dst,
            "quant": quant,
            "key": str(policy.eviction_key(node.block_id, self.state))})

    # ---------------------------------------------------------------- reads
    def lookup(self, tokens: Sequence[int]) -> List[Node]:
        """Longest chain resident in *any* tier from the root; demoted
        blocks on it are promoted back to the device pool before the chain
        is returned, so callers always receive tier-0 payloads.

        Metrics follow the paper's definitions down the ladder: a hit is
        presence in any tier (``tier1_hits``/``tier2_hits`` count the
        slow-tier slices), but a hit is *effective* only when every block
        up to it sits in tier 0 — a partially demoted chain pays the
        promotion copy."""
        if not self.tiered:
            return super().lookup(tokens)
        chain = self._walk(tokens)
        usable: List[Node] = []
        touched_t0: List[Node] = []
        touched_t1: List[Node] = []
        touched_t2: List[Node] = []
        broken = False
        all_t0 = True
        cause = None        # first non-tier-0 node: the chain's blocker
        blocking = [] if self.trace is not None else None
        ineff: Dict[str, int] = {}
        for node in chain:
            in_t0 = node.resident
            in_t1 = node.host_payload is not None
            in_t2 = node.disk_payload is not None
            hit = in_t0 or in_t1 or in_t2
            if not hit:
                broken = True
            if not in_t0:
                all_t0 = False
                if cause is None:
                    cause = blocking_cause(node)
                if blocking is not None:
                    blocking.append((node.uid, blocking_cause(node)))
            effective = hit and not broken and all_t0
            self.metrics_obj.record_access(
                hit=hit, effective=effective,
                tier=1 if in_t1 else (2 if in_t2 else 0), cause=cause)
            if hit and not effective:
                ineff[cause] = ineff.get(cause, 0) + 1
            if hit and not broken:
                usable.append(node)
            if in_t0:
                touched_t0.append(node)
            elif in_t1:
                touched_t1.append(node)
            else:
                touched_t2.append(node)
        for node in reversed(touched_t2):         # leaf first, root last
            self.disk_policy.on_access(node.block_id)
        for node in reversed(touched_t1):
            self.host_policy.on_access(node.block_id)
        for node in reversed(touched_t0):
            self.policy.on_access(node.block_id)
        if self.trace is not None:
            self.trace.instant(
                "store.lookup", "store", self.trace_pid, _TID_STORE,
                args={"blocks": len(chain), "usable": len(usable),
                      "broken": broken, "blocking": blocking,
                      "ineffective": ineff})
        demoted = [n for n in usable if not n.resident]
        if demoted:
            failed = self._promote(demoted,
                                   exclude={n.block_id for n in chain})
            if failed:
                # a promotion timed out or its disk read died: the chain is
                # only usable up to the first unpromoted block — everything
                # past it falls back to prefill recompute (degraded mode)
                for i, n in enumerate(usable):
                    if n.block_id in failed:
                        usable = usable[:i]
                        break
        return usable

    # --------------------------------------------------------------- writes
    def _pre_insert(self, node: Node) -> None:
        if node.host_payload is not None:
            # the chain broke upstream of this block, so the engine
            # recomputed it; the fresh KV supersedes the slow-tier copy
            self._release_host(node)
        if node.disk_payload is not None:
            self._release_disk(node)

    # ----------------------------------------------------- tier-0 pressure
    def _make_room(self, needed: int, exclude: set) -> None:
        super()._make_room(needed, exclude)
        self._flush_demotions()

    def _evict(self, node: Node) -> None:
        """Tier-0 eviction under tiering is a *demotion*: identical
        store-visible event stream (eviction log, counter flips,
        coordination hooks), but the payload moves to the host pool —
        quantized when the store transcodes — instead of dying. When the
        host tier cannot hold the block it skips straight to the disk
        rung; a true eviction only when every lower tier is out of
        room."""
        if not self.tiered:
            return super()._evict(node)
        hbytes = self._host_nbytes(node)
        self._make_host_room(hbytes)
        if (self.host_used + hbytes > self.host_capacity
                or not self.host_pool.free_list):
            if self._demote_past_host(node):
                return
            return super()._evict(node)
        self._trace_move("store.demote", node, src="device", dst="host",
                         policy=self.policy, quant=self.quant is not None)
        host_idx = self.host_pool.alloc()
        self._pending_demotions.append((node.payload, host_idx))
        node.host_payload = host_idx
        node.payload = None
        node.resident = False
        self.used -= node.nbytes
        self.host_used += hbytes
        self.metrics_obj.evictions += 1
        self.metrics_obj.demotions += 1
        self.eviction_log.append(node.block_id)
        self.index.discard(node.block_id)
        self.policy.on_remove(node.block_id)
        # complete -> incomplete flips propagate exactly as for a real
        # eviction: the block left the fast tier (the paper's broadcast
        # moment); replicas track tier-0 residency only
        flipped = self.state.on_evicted(node.block_id)
        # enter the slow tier's victim queue, keyed on post-flip counters
        self.host_policy.on_insert(node.block_id)
        self.host_index.add(node.block_id)
        if self.on_evict is not None:
            self.on_evict(node.block_id, flipped)

    def _demote_past_host(self, node: Node) -> bool:
        """Device victim straight to the disk rung, skipping a host tier
        with no free row — which happens whenever every host row belongs
        to blocks an in-flight promotion is about to vacate. Emits the
        exact tier-0 eviction event stream of a host demotion; only the
        landing tier differs."""
        if not self.disk_tiered:
            return False
        dbytes = self.disk_pool.block_nbytes
        self._make_disk_room(dbytes)
        if (self.disk_used + dbytes > self.disk_capacity
                or not self.disk_pool.free_list):
            return False
        self._trace_move("store.demote", node, src="device", dst="disk",
                         policy=self.policy,
                         quant=self.disk_quant is not None)
        out = self.device_pool.read_rows([node.payload], quant=self.quant)
        blocks, scales = out if self.quant is not None else (out, None)
        blocks, scales = quantlib.transcode_tree_np(
            blocks, scales, self.quant, self.disk_quant)
        disk_idx = self.disk_pool.alloc()
        try:
            self.disk_pool.write_rows([disk_idx], blocks, scales)
        except OSError:
            self.disk_pool.free(disk_idx)
            self._note_disk_io_error("demote_write")
            return False
        if self.disk_quant is not None:
            self.metrics_obj.quantized_demotions += 1
        self.device_pool.free(node.payload)
        node.disk_payload = disk_idx
        node.payload = None
        node.resident = False
        self.used -= node.nbytes
        self.disk_used += dbytes
        self.metrics_obj.evictions += 1
        self.metrics_obj.demotions += 1
        self.metrics_obj.disk_demotions += 1
        self.eviction_log.append(node.block_id)
        self.index.discard(node.block_id)
        self.policy.on_remove(node.block_id)
        flipped = self.state.on_evicted(node.block_id)
        self.disk_policy.on_insert(node.block_id)
        self.disk_index.add(node.block_id)
        if self.on_evict is not None:
            self.on_evict(node.block_id, flipped)
        return True

    def _flush_demotions(self) -> None:
        if not self._pending_demotions:
            return
        dev = [d for d, _ in self._pending_demotions]
        host = [h for _, h in self._pending_demotions]
        self._pending_demotions = []
        if self.quant is None:
            self.host_pool.write_rows(host, self.device_pool.read_rows(dev))
        else:
            blocks, scales = self.device_pool.read_rows(dev,
                                                        quant=self.quant)
            self.host_pool.write_rows(host, blocks, scales)
            self.metrics_obj.quantized_demotions += len(dev)
        for d in dev:
            self.device_pool.free(d)

    # ----------------------------------------------------- tier-1 pressure
    def _make_host_room(self, needed: int) -> None:
        while self.host_used + needed > self.host_capacity:
            victim = self.host_index.pop_min()
            if victim is None:
                return
            self._evict_host(self._nodes[victim])

    def _release_host(self, node: Node) -> None:
        """Free a node's host row (no eviction event). Cancels an unflushed
        demotion of the same row: the device→host copy never happens and
        the device row is freed directly."""
        hp = node.host_payload
        for i, (dev, host) in enumerate(self._pending_demotions):
            if host == hp:
                del self._pending_demotions[i]
                self.device_pool.free(dev)
                break
        self.host_pool.free(hp)
        node.host_payload = None
        self.host_used -= self._host_nbytes(node)
        self.host_index.discard(node.block_id)
        self.host_policy.on_remove(node.block_id)

    def _evict_host(self, node: Node) -> None:
        """Host-tier eviction: demote once more to the disk rung when one
        is configured and has (or can make) room; otherwise the block
        leaves the system entirely (back to recomputable-by-prefill).
        Either way no ``DagState`` transition — a demoted block was
        already out of ``cached`` — so no counter or label changes, and
        nothing to coordinate."""
        if self._demote_to_disk(node):
            return
        self._trace_move("store.evict", node, src="host", dst=None,
                         policy=self.host_policy)
        self._release_host(node)
        node.nbytes = 0
        self.metrics_obj.host_evictions += 1
        self.host_eviction_log.append(node.block_id)
        self._gc_upward(node)

    def _gc_upward(self, node: Node) -> None:
        """Skeleton GC after a final eviction: unlike ``complete_request``
        pruning there is no chain list in hand, so walk parent links while
        nodes are garbage (non-resident in every tier, childless,
        unreferenced)."""
        while (node is not None and node.parent is not None
               and self._is_garbage(node)):
            parent = node.parent
            self._forget_node(node)
            node = parent

    # ----------------------------------------------------- tier-2 pressure
    def _demote_to_disk(self, node: Node) -> bool:
        """Move a host-tier victim's row to the disk pool, transcoding if
        the disk format differs. Returns False (caller finishes the kill)
        when no disk tier is configured or it cannot make room."""
        if not self.disk_tiered:
            return False
        dbytes = self.disk_pool.block_nbytes
        self._make_disk_room(dbytes)
        if (self.disk_used + dbytes > self.disk_capacity
                or not self.disk_pool.free_list):
            return False
        self._trace_move(
            "store.demote", node, src="host", dst="disk",
            policy=self.host_policy,
            quant=self.disk_quant is not None and self.disk_quant != self.quant)
        # the victim's host row may still be an unflushed pending demotion
        # (selected by _make_host_room inside the same _make_room batch) —
        # its bytes must land in host memory before we can read them
        if any(h == node.host_payload for _, h in self._pending_demotions):
            self._flush_demotions()
        out = self.host_pool.read_rows([node.host_payload])
        blocks, scales = out if self.quant is not None else (out, None)
        blocks, scales = quantlib.transcode_tree_np(
            blocks, scales, self.quant, self.disk_quant)
        disk_idx = self.disk_pool.alloc()
        try:
            self.disk_pool.write_rows([disk_idx], blocks, scales)
        except OSError:
            self.disk_pool.free(disk_idx)
            self._note_disk_io_error("demote_write")
            return False
        if self.disk_quant is not None and self.disk_quant != self.quant:
            self.metrics_obj.quantized_demotions += 1
        self._release_host(node)
        node.disk_payload = disk_idx
        self.disk_used += dbytes
        self.metrics_obj.disk_demotions += 1
        self.disk_policy.on_insert(node.block_id)
        self.disk_index.add(node.block_id)
        return True

    def _make_disk_room(self, needed: int) -> None:
        while self.disk_used + needed > self.disk_capacity:
            victim = self.disk_index.pop_min()
            if victim is None:
                return
            self._evict_disk(self._nodes[victim])

    def _release_disk(self, node: Node) -> None:
        """Free a node's disk row (no eviction event)."""
        self.disk_pool.free(node.disk_payload)
        node.disk_payload = None
        self.disk_used -= self.disk_pool.block_nbytes
        self.disk_index.discard(node.block_id)
        self.disk_policy.on_remove(node.block_id)

    def _evict_disk(self, node: Node) -> None:
        """The ladder's last rung: the block dies for real."""
        self._trace_move("store.evict", node, src="disk", dst=None,
                         policy=self.disk_policy)
        self._release_disk(node)
        node.nbytes = 0
        self.metrics_obj.disk_evictions += 1
        self.disk_eviction_log.append(node.block_id)
        self._gc_upward(node)

    # ------------------------------------------------------------ promotion
    def _promote(self, nodes: List[Node], exclude: Set[str]) -> Set[str]:
        """Bring demoted blocks back on-device: make tier-0 room (which may
        demote colder blocks — the whole looked-up chain is excluded), then
        ONE host→device transfer + scatter per source tier for the batch
        (``promotion_dispatches``), dequantizing on device when the source
        tier is transcoded. Disk rows promote straight to the device pool —
        their bytes stream through host RAM, not through host-pool rows, so
        a promotion never needs host-tier room. Mirrors
        ``CacheManager.load_from_disk``: the blocks re-enter the fast tier
        as loads, flipping their peer groups complete again.

        Returns the block ids that did NOT promote: a stalled promotion
        past the plan's timeout abandons the whole batch *before* any
        mutation (the blocks simply stay demoted — recomputable), and a
        disk-tier read error kills the affected blocks (their bytes are
        unreachable). The caller truncates the usable chain accordingly."""
        if self.faults is not None:
            stall = self.faults.promotion_stall()
            if stall > 0.0:
                if stall > self.faults.plan.promotion_timeout:
                    # abandon before touching indexes or payloads: the
                    # chain stays demoted and the engine recomputes — a
                    # stalled disk can never wedge the step
                    self.metrics_obj.promotion_timeouts += 1
                    if self.trace is not None:
                        self.trace.instant(
                            "fault.promotion_timeout", "store",
                            self.trace_pid, _TID_STORE,
                            args={"blocks": len(nodes), "stall": stall})
                    return {n.block_id for n in nodes}
                self.pending_stall += stall
                self.metrics_obj.promotion_stalls += 1
                if self.trace is not None:
                    self.trace.instant(
                        "fault.promotion_stall", "store", self.trace_pid,
                        _TID_STORE,
                        args={"blocks": len(nodes), "stall": stall})
        for node in nodes:
            self.host_index.discard(node.block_id)
            self.disk_index.discard(node.block_id)
        self._make_room(sum(n.nbytes for n in nodes), exclude=exclude)
        dev_rows = [self.device_pool.alloc() for _ in nodes]
        failed: Set[str] = set()
        for pool, spec, srcs in (
                (self.host_pool, self.quant,
                 [(n, d) for n, d in zip(nodes, dev_rows)
                  if n.host_payload is not None]),
                (self.disk_pool, self.disk_quant,
                 [(n, d) for n, d in zip(nodes, dev_rows)
                  if n.disk_payload is not None])):
            if not srcs:
                continue
            src_rows = [n.host_payload if pool is self.host_pool
                        else n.disk_payload for n, _ in srcs]
            dst_rows = [d for _, d in srcs]
            try:
                out = pool.read_rows(src_rows)
            except OSError:
                # the disk tier lost these bytes: free the reserved device
                # rows, kill the blocks (no copy survives anywhere), and
                # let quarantine accounting decide the tier's fate
                for n, d in srcs:
                    failed.add(n.block_id)
                    self.device_pool.free(d)
                    self._release_disk(n)
                    n.nbytes = 0
                    self.metrics_obj.disk_evictions += 1
                    self.disk_eviction_log.append(n.block_id)
                self._note_disk_io_error("promote_read")
                continue
            if pool is self.disk_pool:
                self._disk_errors = 0
            if spec is None:
                self.device_pool.write_rows(dst_rows, out)
            else:
                blocks, scales = out
                self.device_pool.write_rows(dst_rows, blocks, scales)
                self.metrics_obj.dequantized_promotions += len(src_rows)
            self.metrics_obj.promotion_dispatches += 1
        for node, dev in zip(nodes, dev_rows):
            if node.block_id in failed:
                self._gc_upward(node)
                continue
            if self.trace is not None:
                self._trace_move(
                    "store.promote", node,
                    src="host" if node.host_payload is not None else "disk",
                    dst="device",
                    policy=(self.host_policy if node.host_payload is not None
                            else self.disk_policy))
            if node.host_payload is not None:
                self.host_pool.free(node.host_payload)
                node.host_payload = None
                self.host_used -= self._host_nbytes(node)
                self.host_policy.on_remove(node.block_id)
            else:
                self.disk_pool.free(node.disk_payload)
                node.disk_payload = None
                self.disk_used -= self.disk_pool.block_nbytes
                self.disk_policy.on_remove(node.block_id)
                self.metrics_obj.disk_promotions += 1
            node.payload = dev
            node.resident = True
            self.used += node.nbytes
            self.metrics_obj.promotions += 1
            self.state.on_loaded(node.block_id)   # flips groups complete
            self.index.add(node.block_id)
            if self.on_status is not None:
                self.on_status("loaded", node.block_id)
        for node in reversed(nodes):              # leaf first, root last
            if node.block_id not in failed:
                self.policy.on_insert(node.block_id)
        return failed

    # --------------------------------------------- disk-fault bookkeeping
    def _note_disk_io_error(self, site: str) -> None:
        """One disk I/O error happened (injected or real): count it and
        quarantine the tier after ``quarantine_after`` consecutive
        failures."""
        self.metrics_obj.disk_io_errors += 1
        self._disk_errors += 1
        if self.faults is not None:
            self.faults.count("fault.disk_io")
        if self.trace is not None:
            self.trace.instant(
                "fault.disk_io", "store", self.trace_pid, _TID_STORE,
                args={"site": site, "consecutive": self._disk_errors})
        threshold = (self.faults.plan.quarantine_after
                     if self.faults is not None else 3)
        if not self.disk_quarantined and self._disk_errors >= threshold:
            self._quarantine_disk()

    def _quarantine_disk(self) -> None:
        """Take a failing disk tier out of rotation: every disk-resident
        block dies (its bytes are untrustworthy), future demotions skip
        the rung (``disk_tiered`` goes False), and the store degrades to
        the PR 5 two-tier semantics — eviction + prefill recompute — with
        zero exceptions escaping to the engine."""
        if self.disk_quarantined:
            return
        self.disk_quarantined = True
        self.metrics_obj.disk_quarantines += 1
        victims = sorted((n for n in self._nodes.values()
                          if n.disk_payload is not None),
                         key=lambda n: n.uid)
        if self.trace is not None:
            self.trace.instant(
                "fault.disk_quarantine", "store", self.trace_pid,
                _TID_STORE, args={"blocks_lost": len(victims),
                                  "errors": self._disk_errors})
        for node in victims:
            self._release_disk(node)
            node.nbytes = 0
            self.metrics_obj.disk_evictions += 1
            self.disk_eviction_log.append(node.block_id)
            self._gc_upward(node)

    # -------------------------------------------------------------- lifetime
    def close(self) -> None:
        """Deterministic teardown of file-backed resources (the disk
        pool's memmap row files)."""
        if self.disk_pool is not None:
            self.disk_pool.close()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        m = super().metrics()
        m["host_used_bytes"] = self.host_used
        m["host_capacity_bytes"] = self.host_capacity
        if self.disk_tiered or self.disk_capacity > 0:
            m["disk_used_bytes"] = self.disk_used
            m["disk_capacity_bytes"] = self.disk_capacity
        return m

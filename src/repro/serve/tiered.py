"""Tiered KV store: LERC-aware demotion to a host-memory tier (PR 4).

``core`` honors the paper's all-or-nothing property with a two-tier
MemoryTier/DiskTier store: eviction moves a block to the slow tier, and a
task only speeds up when *every* peer sits in the fast tier. This module
gives the serving data plane the same shape. Tier 0 is the device-resident
``KVBlockPool``; tier 1 is a preallocated ``HostBlockPool``. Under device
pressure a prefix-cache block *demotes* — one jitted device→host row copy —
instead of dying, and a later lookup that walks over demoted blocks
*promotes* the usable chain back with a host→device scatter, paying a copy
instead of a prefill recompute.

Placement policy is the paper's machinery twice over:

* **Demotion victims** are chosen by the store's existing
  ``Policy``/``EvictionIndex`` over the shared ``DagState`` counters — so
  LERC demotes members of broken peer groups (ERC 0) first and keeps
  complete chains wholly on-device. An *effective* hit remains
  tier-0-only: a partially demoted chain is "incomplete" in the paper's
  sense and pays the max-over-blocks promotion copy before it is usable —
  the all-or-nothing bottleneck, now one tier down.
* **Final eviction out of the host tier** runs a second policy-driven
  ``EvictionIndex`` over the same counters. A demoted block is never in
  ``DagState.cached``, so every peer group through it is incomplete and a
  completeness-aware key degrades gracefully to (reference count,
  recency) — host retention follows who still *references* a chain, not
  who recently used it.

Tier-0 state transitions (demotion = eviction from the fast tier) keep
the exact event stream the single-tier store emits: same
``eviction_log``, same ``DagState.on_evicted`` completeness flips, same
``on_evict``/``on_status`` coordination hooks — so a sharded frontend
with tiered shards stays replica-coherent with no protocol changes, and
with the host tier disabled this class is op-for-op a ``PrefixStore``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

from ..core import EvictionIndex, Policy, make_policy
from .host_pool import HostBlockPool
from .kv_pool import KVBlockPool
from .prefix_store import Node, PrefixStore


class TieredKVStore(PrefixStore):
    """Two-tier prefix store: device pool (tier 0) + host pool (tier 1).

    Construct like a ``PrefixStore`` plus a host-tier byte budget; the
    engine attaches the actual pools (it owns the cache template) via
    ``attach_pools``. With ``host_capacity_bytes == 0`` (or no pools
    attached) every code path delegates to the base class, bit-identical
    to a single-tier store.
    """

    def __init__(self, capacity_bytes: int,
                 policy: Union[str, Policy] = "lerc",
                 block_tokens: int = 16, *,
                 host_capacity_bytes: int = 0,
                 host_policy: Union[str, Policy, None] = None) -> None:
        super().__init__(capacity_bytes, policy, block_tokens=block_tokens)
        self.host_capacity = host_capacity_bytes
        self.host_used = 0
        if host_policy is None:
            host_policy = make_policy(self.policy.name)
        elif isinstance(host_policy, str):
            host_policy = make_policy(host_policy)
        self.host_policy = host_policy
        self.host_index = EvictionIndex(self.host_policy, self.state)
        self.device_pool: Optional[KVBlockPool] = None
        self.host_pool: Optional[HostBlockPool] = None
        self.host_eviction_log: List[str] = []
        # demotions batched per ``_make_room`` call: (device row, host row).
        # Victim selection interleaves with per-victim state updates, but
        # the byte movement happens in ONE jitted gather + device_get at
        # the end of the batch, before any freed device row can be reused.
        self._pending_demotions: List[Tuple[int, int]] = []

    # --------------------------------------------------------------- wiring
    def attach_pools(self, device_pool: KVBlockPool,
                     host_pool: HostBlockPool) -> None:
        self.device_pool = device_pool
        self.host_pool = host_pool
        # fallback/final device evictions still free pool rows directly
        self.evict_payload = device_pool.free

    @property
    def tiered(self) -> bool:
        return (self.host_capacity > 0 and self.host_pool is not None
                and self.host_pool.num_blocks > 0)

    # ---------------------------------------------------------------- reads
    def lookup(self, tokens: Sequence[int]) -> List[Node]:
        """Longest chain resident in *either* tier from the root; demoted
        blocks on it are promoted back to the device pool before the chain
        is returned, so callers always receive tier-0 payloads.

        Metrics follow the paper's definitions one tier down: a hit is
        presence in any tier (``tier1_hits`` counts the slow-tier slice),
        but a hit is *effective* only when every block up to it sits in
        tier 0 — a partially demoted chain pays the promotion copy."""
        if not self.tiered:
            return super().lookup(tokens)
        chain = self._walk(tokens)
        usable: List[Node] = []
        touched_t0: List[Node] = []
        touched_t1: List[Node] = []
        broken = False
        all_t0 = True
        for node in chain:
            in_t0 = node.resident
            in_t1 = node.host_payload is not None
            hit = in_t0 or in_t1
            if not hit:
                broken = True
            if in_t1:
                all_t0 = False
            self.metrics_obj.record_access(
                hit=hit, effective=hit and not broken and all_t0,
                tier=1 if in_t1 else 0)
            if hit and not broken:
                usable.append(node)
            if in_t0:
                touched_t0.append(node)
            elif in_t1:
                touched_t1.append(node)
        for node in reversed(touched_t1):         # leaf first, root last
            self.host_policy.on_access(node.block_id)
        for node in reversed(touched_t0):
            self.policy.on_access(node.block_id)
        demoted = [n for n in usable if n.host_payload is not None]
        if demoted:
            self._promote(demoted, exclude={n.block_id for n in chain})
        return usable

    # --------------------------------------------------------------- writes
    def _pre_insert(self, node: Node) -> None:
        if node.host_payload is not None:
            # the chain broke upstream of this block, so the engine
            # recomputed it; the fresh KV supersedes the host copy
            self._release_host(node)

    # ----------------------------------------------------- tier-0 pressure
    def _make_room(self, needed: int, exclude: set) -> None:
        super()._make_room(needed, exclude)
        self._flush_demotions()

    def _evict(self, node: Node) -> None:
        """Tier-0 eviction under tiering is a *demotion*: identical
        store-visible event stream (eviction log, counter flips,
        coordination hooks), but the payload moves to the host pool
        instead of dying. Falls back to a true eviction when the host
        tier cannot hold the block."""
        if not self.tiered:
            return super()._evict(node)
        self._make_host_room(node.nbytes)
        if (self.host_used + node.nbytes > self.host_capacity
                or not self.host_pool.free_list):
            return super()._evict(node)
        host_idx = self.host_pool.alloc()
        self._pending_demotions.append((node.payload, host_idx))
        node.host_payload = host_idx
        node.payload = None
        node.resident = False
        self.used -= node.nbytes
        self.host_used += node.nbytes
        self.metrics_obj.evictions += 1
        self.metrics_obj.demotions += 1
        self.eviction_log.append(node.block_id)
        self.index.discard(node.block_id)
        self.policy.on_remove(node.block_id)
        # complete -> incomplete flips propagate exactly as for a real
        # eviction: the block left the fast tier (the paper's broadcast
        # moment); replicas track tier-0 residency only
        flipped = self.state.on_evicted(node.block_id)
        # enter the slow tier's victim queue, keyed on post-flip counters
        self.host_policy.on_insert(node.block_id)
        self.host_index.add(node.block_id)
        if self.on_evict is not None:
            self.on_evict(node.block_id, flipped)

    def _flush_demotions(self) -> None:
        if not self._pending_demotions:
            return
        dev = [d for d, _ in self._pending_demotions]
        host = [h for _, h in self._pending_demotions]
        self._pending_demotions = []
        self.host_pool.write_rows(host, self.device_pool.read_rows(dev))
        for d in dev:
            self.device_pool.free(d)

    # ----------------------------------------------------- tier-1 pressure
    def _make_host_room(self, needed: int) -> None:
        while self.host_used + needed > self.host_capacity:
            victim = self.host_index.pop_min()
            if victim is None:
                return
            self._evict_host(self._nodes[victim])

    def _release_host(self, node: Node) -> None:
        """Free a node's host row (no eviction event). Cancels an unflushed
        demotion of the same row: the device→host copy never happens and
        the device row is freed directly."""
        hp = node.host_payload
        for i, (dev, host) in enumerate(self._pending_demotions):
            if host == hp:
                del self._pending_demotions[i]
                self.device_pool.free(dev)
                break
        self.host_pool.free(hp)
        node.host_payload = None
        self.host_used -= node.nbytes
        node.nbytes = 0
        self.host_index.discard(node.block_id)
        self.host_policy.on_remove(node.block_id)

    def _evict_host(self, node: Node) -> None:
        """Final eviction: the block leaves the system entirely (back to
        recomputable-by-prefill). No ``DagState`` transition — a demoted
        block was already out of ``cached`` — so no counter or label
        changes, and nothing to coordinate."""
        self._release_host(node)
        self.metrics_obj.host_evictions += 1
        self.host_eviction_log.append(node.block_id)
        self._gc_upward(node)

    def _gc_upward(self, node: Node) -> None:
        """Skeleton GC after a host eviction: unlike ``complete_request``
        pruning there is no chain list in hand, so walk parent links while
        nodes are garbage (non-resident in both tiers, childless,
        unreferenced)."""
        while (node is not None and node.parent is not None
               and self._is_garbage(node)):
            parent = node.parent
            self._forget_node(node)
            node = parent

    # ------------------------------------------------------------ promotion
    def _promote(self, nodes: List[Node], exclude: Set[str]) -> None:
        """Bring demoted blocks back on-device: make tier-0 room (which may
        demote colder blocks — the whole looked-up chain is excluded), then
        one host→device scatter for the batch. Mirrors
        ``CacheManager.load_from_disk``: the blocks re-enter the fast tier
        as loads, flipping their peer groups complete again."""
        for node in nodes:
            self.host_index.discard(node.block_id)
        self._make_room(sum(n.nbytes for n in nodes), exclude=exclude)
        host_rows = [n.host_payload for n in nodes]
        dev_rows = [self.device_pool.alloc() for _ in nodes]
        self.device_pool.write_rows(dev_rows,
                                    self.host_pool.read_rows(host_rows))
        for node, dev in zip(nodes, dev_rows):
            self.host_pool.free(node.host_payload)
            node.host_payload = None
            node.payload = dev
            node.resident = True
            self.host_used -= node.nbytes
            self.used += node.nbytes
            self.host_policy.on_remove(node.block_id)
            self.metrics_obj.promotions += 1
            self.state.on_loaded(node.block_id)   # flips groups complete
            self.index.add(node.block_id)
            if self.on_status is not None:
                self.on_status("loaded", node.block_id)
        for node in reversed(nodes):              # leaf first, root last
            self.policy.on_insert(node.block_id)

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        m = super().metrics()
        m["host_used_bytes"] = self.host_used
        m["host_capacity_bytes"] = self.host_capacity
        return m

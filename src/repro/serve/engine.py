"""Continuous-batching serve engine over a device-resident paged KV pool,
with a LERC prefix cache underneath.

The serving data plane is built so the hot path is dominated by real
compute, not Python-loop and PCIe overhead — the regime where the paper's
claim (coordinated caching speeds up *jobs*) is measurable:

* **Chunked prefill** — each engine step feeds up to ``prefill_chunk``
  prompt tokens per slot through one batched ``decode_step``, so a P-token
  prompt costs ~ceil(P/chunk) dispatches instead of ~P. Prefill-chunk
  slots and decode slots share the dispatch; decode rows are right-padded
  and masked.
* **Zero-copy paged attention** (``paged=True``, PR 5) — the
  ``KVBlockPool`` is the ONLY KV storage. Each slot owns a *block table*
  (host-side list of pool rows); a prefix hit appends the store's rows to
  the table (zero dispatches, zero copies), new tokens are written by the
  model straight into the slot's tail pool rows, attention streams from
  the rows the table names (``kernels.paged_attention`` on TPU, the same
  ``_sdpa`` numerics via an XLA page gather elsewhere), and publish is an
  ownership transfer of the already-written rows to the store. Rows are
  refcounted: evicting a block another slot is still reading defers the
  actual reclaim to that slot's completion. The per-slot contiguous
  ``(B, max_seq)`` decode cache does not exist in this mode — its bytes
  are free to grow the pool.
* **Gather fallback** (``paged=False``, the PR 2 data plane) — per-slot
  contiguous caches; a hit is a jitted gather pool→slot, publish a jitted
  scatter slot→pool. Retained for rolling/recurrent layer patterns, whose
  KV layout is not absolute-position.
* **Pipelined host readback** — the argmax token of step N is routed into
  step N+1's feed *on device* (decode feeds never round-trip through
  host), so the engine only blocks on a device→host sync when a request
  finishes (or every step when EOS detection is on). ``metrics()`` counts
  the avoided syncs.

Store-visible behavior (the sequence of ``register_request`` / ``lookup``
/ ``insert`` / ``complete_request`` calls and therefore every eviction
decision) is identical across both data planes and the legacy engine on
uniform-length workloads; ``tests/test_engine_equivalence.py`` proves
token-identical generations and bit-identical eviction logs paged vs
gather vs ``LegacyServeEngine`` vs the brute-force
``ReferencePrefixStore``.
"""
from __future__ import annotations

import itertools
import warnings
from collections import deque
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_decode_cache
from ..models.common import ModelConfig
from ..obs.trace import (TID_ENGINE as _TID_ENGINE, TID_REQ as _TID_REQ,
                         TID_SCHED as _TID_SCHED, TID_STORE as _TID_STORE)
from ..sharding import KVShardCtx, serve_tp_context
from .disk_pool import DiskBlockPool
from .host_pool import HostBlockPool
from .kv_pool import KVBlockPool, chain_block_nbytes
from .prefix_store import PrefixStore
from .scheduler import QueueFull, Scheduler, StepCostModel, make_scheduler
from .tiered import TieredKVStore

# pool rows a default-constructed engine starts with when the store's byte
# budget is effectively unbounded (the pool doubles on demand)
_DEFAULT_POOL_BLOCKS = 256


@lru_cache(maxsize=None)
def _step_fn(cfg: ModelConfig, paged: bool, eos_id: int,
             kv_shard: Optional[KVShardCtx] = None):
    """One shared jitted step per (hashable) config, data plane, EOS id,
    and serve-TP mesh: engines spun up on the same model reuse every
    compiled (B, S) specialization instead of retracing behind a fresh
    closure. The KV
    argument (per-slot cache or pool buffers) is donated so XLA updates
    it in place; ``prev``/``use_prev`` route the previous step's argmax
    into decode feeds without a host round-trip.

    ``done`` is the device-side finished mask (PR 6): when EOS detection
    is on, the mask accumulates ``emitted-token == eos_id`` per slot *on
    device*, so the engine only syncs the (B,) mask every
    ``eos_interval`` steps instead of the whole token vector every step —
    EOS mode rides the readback pipeline like everything else."""

    # meta rows: 0 = per-slot position, 1 = real tokens this step,
    # 2 = route the previous argmax into column 0 (decode feed),
    # 3 = this step's output counts as a generated token (EOS-eligible),
    # 4 = clear the slot's done bit (slot re-admitted) — packed into ONE
    # (5, B) host→device upload per step
    def _advance(out_tok, meta, done):
        if eos_id < 0:
            return done
        emit = meta[3].astype(bool)
        reset = meta[4].astype(bool)
        return (done & ~reset) | (emit & (out_tok == eos_id))

    if paged:
        def _step(p, pool, t, meta, tables, prev, done):
            pos, lens, use_prev = meta[0], meta[1], meta[2].astype(bool)
            t = t.at[:, 0].set(jnp.where(use_prev, prev, t[:, 0]))
            logits, new_pool = decode_step(cfg, p, pool, t, pos,
                                           seq_lens=lens,
                                           paged_tables=tables,
                                           kv_shard=kv_shard)
            out = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return out, new_pool, _advance(out, meta, done)

        return jax.jit(_step, donate_argnums=(1,))

    def _step(p, c, t, meta, prev, done):
        pos, lens, use_prev = meta[0], meta[1], meta[2].astype(bool)
        t = t.at[:, 0].set(jnp.where(use_prev, prev, t[:, 0]))
        logits, new_cache = decode_step(cfg, p, c, t, pos, seq_lens=lens)
        out = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return out, new_cache, _advance(out, meta, done)

    return jax.jit(_step, donate_argnums=(1,))


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    prefix_rid: int = -1            # id inside the PrefixStore
    slot: int = -1
    pos: int = 0                    # next position to fill
    generated: List[int] = field(default_factory=list)
    n_generated: int = 0            # tokens emitted (generated may lag:
                                    # pipelined readback materializes lazily)
    prefill_skipped: int = 0
    done: bool = False
    cancelled: bool = False
    # front-door timing, on the engine's virtual clock (scheduler SLOs)
    arrival: float = 0.0
    deadline: Optional[float] = None    # absolute TTFT deadline, or None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # failover re-admission (repro.faults): how many times a crash has
    # requeued this request, and the backoff gate before it may re-admit
    retries: int = 0
    not_before: float = 0.0
    # un-synced per-step token vectors (pipelined readback)
    _lazy_out: List = field(default_factory=list, repr=False)


def _kv_leaves(cache) -> List[Tuple[Tuple[str, ...], jax.Array]]:
    out = []

    def walk(t, path=()):
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, path + (k,))
        else:
            out.append((path, t))

    walk(cache)
    return out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_seq: int = 256, store: Optional[PrefixStore] = None,
                 eos_id: int = -1, prefill_chunk: int = 8,
                 pool_blocks: Optional[int] = None,
                 paged: bool = False,
                 scheduler: Union[str, Scheduler, None] = None,
                 max_queue: Optional[int] = None,
                 clock: Optional[StepCostModel] = None,
                 eos_interval: int = 8, tp: int = 1,
                 kv_shard: Optional[KVShardCtx] = None) -> None:
        template = init_decode_cache(cfg, 1, 8)
        for path, _ in _kv_leaves(template):
            assert path[-1] in ("k", "v"), (
                "ServeEngine supports uniform-KV patterns; got leaf "
                f"{'/'.join(path)}")
        absolute_kv = set(cfg.layer_pattern) <= {"G", "M"}
        if prefill_chunk > 1 and not absolute_kv:
            warnings.warn(
                "chunked prefill needs absolute-position KV caches; "
                f"pattern {cfg.layer_pattern!r} has rolling/recurrent "
                "layers — clamping prefill_chunk to 1", stacklevel=2)
            prefill_chunk = 1
        if paged and not absolute_kv:
            warnings.warn(
                "paged attention needs absolute-position KV caches; "
                f"pattern {cfg.layer_pattern!r} has rolling/recurrent "
                "layers — falling back to the gather engine", stacklevel=2)
            paged = False
        # rolling-window (L) KV keeps only the last `window` tokens, so a
        # chain block cannot be restored into it: non-absolute patterns
        # run the full store machinery (lookups, evictions, coordination)
        # but pay prefill recompute instead of a restore. (The PR 2 assert
        # used to reject these configs outright; the restore path it
        # guarded was never valid for them.)
        self.restore_prefix = absolute_kv
        # ----- serve tensor parallelism (PR 7): shard the paged KV pool
        # (and the attention compute reading it) over a 1-D model mesh.
        # Params and per-step host arrays are replicated; block tables,
        # refcounts, and the whole store stay host-global — a pool row
        # index means the same block on every shard.
        if kv_shard is None and tp > 1:
            kv_shard = serve_tp_context(tp)
        if kv_shard is not None:
            if not paged:
                raise ValueError(
                    "tensor parallelism shards the paged data plane; "
                    f"pattern {cfg.layer_pattern!r} (or --no-paged-"
                    "attention) runs the gather engine, which is tp=1 only")
            kv_shard.validate(cfg)
        self.kv_shard = kv_shard
        self.tp = kv_shard.tp if kv_shard is not None else 1
        self._put = (jnp.asarray if kv_shard is None else
                     (lambda x: jax.device_put(jnp.asarray(x),
                                               kv_shard.replicated())))
        if kv_shard is not None:
            params = jax.device_put(params, kv_shard.replicated())
        self.cfg = cfg
        self.params = params
        self.B = max_slots
        self.max_seq = max_seq
        self.store = store or PrefixStore(capacity_bytes=1 << 62,
                                          policy="lerc")
        self.eos_id = eos_id
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.paged = bool(paged)

        # ----- paged pool: sized so the store's byte budget, not the pool,
        # is always the binding constraint (bounded budgets evict — and
        # free indices — before alloc; unbounded ones rely on growth). In
        # paged mode the pool additionally carries each slot's private tail
        # rows — the bytes the per-slot contiguous cache used to pin.
        bt = self.store.block_tokens
        self.table_width = -(-max_seq // bt)
        blk_bytes = chain_block_nbytes(template, bt)
        if pool_blocks is None:
            by_capacity = -(-self.store.capacity // max(blk_bytes, 1))
            pool_blocks = int(min(by_capacity, _DEFAULT_POOL_BLOCKS))
            if self.paged:
                pool_blocks += self.B * self.table_width + 1
        self.pool = KVBlockPool(template, bt, pool_blocks,
                                shard_ctx=self.kv_shard)
        if self.paged:
            self.cache = None
            # every right-padded / inactive-slot token is scattered into
            # this reserved row, so real rows only ever see real writes
            self._junk_row = self.pool.alloc()
            assert self._junk_row == 0
            self._tables: List[List[int]] = [[] for _ in range(self.B)]
            # tables only change on admission/completion, not per decode
            # step — keep the device copy and re-upload only when dirty
            self._tables_dev = None
            self._tables_dirty = True
        else:
            self.cache = init_decode_cache(cfg, self.B, max_seq)
        if isinstance(self.store, TieredKVStore):
            # tier 1: host-side pool sized to the store's host byte budget
            # (0 rows when the tier is disabled — the store then behaves
            # op-for-op like a plain PrefixStore). With a quant format the
            # pool stores transcoded rows, so the same budget holds
            # ~itemsize-ratio more blocks. Tier 2, when budgeted, is a
            # memmap pool mirroring the host layout.
            host_pool = HostBlockPool.for_device_pool(
                template, self.pool, self.store.host_capacity,
                quant=self.store.quant)
            disk_pool = None
            if self.store.disk_capacity > 0:
                disk_pool = DiskBlockPool.for_device_pool(
                    template, self.pool, self.store.disk_capacity,
                    quant=self.store.disk_quant,
                    directory=self.store.disk_dir)
            self.store.attach_pools(self.pool, host_pool, disk_pool)
        else:
            self.store.evict_payload = self.pool.free

        self._step = _step_fn(cfg, self.paged, eos_id, self.kv_shard)
        self._prev_out = self._put(jnp.zeros((self.B,), jnp.int32))
        self._done_dev = self._put(jnp.zeros((self.B,), bool))
        self._last_step_avals = None    # shapes of the newest dispatch
        self._rid = itertools.count(1)
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * self.B
        # ----- front door (PR 6): step scheduling, admission control, and
        # a deterministic virtual clock for SLO accounting. The default
        # FCFS scheduler reproduces the pre-scheduler step loop exactly.
        self.scheduler = (make_scheduler(scheduler)
                          if isinstance(scheduler, str)
                          else scheduler or Scheduler())
        self.max_queue = max_queue
        self.clock = clock or StepCostModel()
        if getattr(self.scheduler, "clock", False) is None:
            # cost-aware schedulers price chunks on the engine's own clock
            self.scheduler.clock = self.clock
        self.now = 0.0
        self.eos_interval = max(int(eos_interval), 1)
        self._fresh_slots: set = set()  # admitted since the last dispatch
        self.steps = 0
        self.decoded_tokens = 0
        self.prefill_tokens = 0
        self.prefill_tokens_skipped = 0
        self.transfer_dispatches = 0    # gather/scatter/copy-on-write
        self.readback_syncs = 0         # device→host blocking reads
        self.rejected = 0               # backpressure sheds
        self.cancellations = 0
        # obs: an attached ``repro.obs.TraceRecorder`` (None = every
        # instrumentation site is one predicate — bit-identical behavior,
        # see tests/test_obs.py)
        self.trace = None
        self._trace_pid = 0

    # ------------------------------------------------------------------ obs
    def attach_trace(self, recorder, pid: int = 0,
                     name: str = "engine") -> None:
        """Wire a ``TraceRecorder`` through every layer of this engine:
        step phases + scheduler decisions + request lifecycle (this
        class), and store events (the prefix store). ``pid`` namespaces
        the events when several engines (sharded frontend) share one
        recorder."""
        self.trace = recorder
        self._trace_pid = pid
        for tid in (_TID_ENGINE, _TID_SCHED, _TID_STORE, _TID_REQ):
            recorder.label(pid, name, tid=tid)
        self.store.trace = recorder
        self.store.trace_pid = pid
        recorder.vt = self.now

    def _aid(self, req: "Request") -> str:
        """Async-track id for a request: pid-qualified, because rids are
        per-engine counters that collide across shards."""
        return f"{self._trace_pid}:{req.rid}"

    def _trace_req_end(self, r: "Request") -> None:
        """Close a request's lifecycle track with everything
        ``latency_stats`` needs, so reports reconstruct TTFT/TPOT
        percentiles from the trace alone."""
        if self.trace is None:
            return
        self.trace.end_async(
            "req", self._aid(r), "request", self._trace_pid, _TID_REQ,
            args={"rid": r.rid, "arrival": r.arrival, "deadline": r.deadline,
                  "first_token_at": r.first_token_at,
                  "finished_at": r.finished_at,
                  "n_generated": len(r.generated),
                  "cancelled": r.cancelled,
                  "prefill_skipped": r.prefill_skipped})

    # ------------------------------------------------------------- requests
    def submit(self, prompt: Sequence[int], max_new: int = 16, *,
               deadline: Optional[float] = None,
               arrival: Optional[float] = None) -> Request:
        """Enqueue a request. ``deadline`` is an *absolute* TTFT deadline
        on the engine's virtual clock (None = best-effort); ``arrival``
        backdates the request to its true arrival time when a trace loop
        submits it a fraction of a step late. Raises ``QueueFull`` when
        admission control is on and the queue is at ``max_queue``."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.rejected += 1
            retry_after = self.retry_after()
            if self.trace is not None:
                self.trace.instant(
                    "rejected", "request", self._trace_pid, _TID_REQ,
                    args={"queued": len(self.queue),
                          "retry_after": retry_after})
            raise QueueFull(f"queue at max_queue={self.max_queue}",
                            depth=len(self.queue), retry_after=retry_after)
        req = Request(next(self._rid), list(prompt), max_new,
                      arrival=self.now if arrival is None else arrival,
                      deadline=deadline)
        req.prefix_rid = self.store.register_request(prompt)
        self.queue.append(req)
        if self.trace is not None:
            self.trace.begin_async(
                "req", self._aid(req), "request", self._trace_pid, _TID_REQ,
                args={"rid": req.rid, "prompt_tokens": len(req.prompt),
                      "max_new": req.max_new, "deadline": req.deadline},
                vt=req.arrival)
        return req

    def retry_after(self) -> float:
        """Backpressure hint stamped on ``QueueFull``: the estimated
        virtual-clock wait until a queue slot frees — the nearest-to-done
        active request's remaining steps priced by the engine's
        ``StepCostModel`` (decode steps at the current batch size)."""
        active = [r for r in self.slots if r is not None]
        per_step = float(self.clock(0, max(len(active), 1), 0))
        if not active:
            return per_step
        steps_left = min(
            -(-max(len(r.prompt) - r.pos, 0) // self.prefill_chunk)
            + max(r.max_new - r.n_generated, 0)
            for r in active)
        return max(steps_left, 1) * per_step

    def cancel(self, req: Request) -> bool:
        """Cancel a request at any point in its lifetime — queued,
        prefilling, or mid-decode. Frees the slot and (paged plane) the
        slot's block-table rows *immediately*: tail rows return to the
        pool, shared store rows drop the slot's reference, and the
        store's pending-chain references retire so eviction stops
        protecting the abandoned chain. Tokens already computed remain
        readable on the returned request. Call between steps."""
        if req.done:
            return False
        req.done = True
        req.cancelled = True
        self.cancellations += 1
        if req.slot >= 0 and self.slots[req.slot] is req:
            self._release_slot(req)
        else:
            try:
                self.queue.remove(req)
            except ValueError:
                pass
        self.store.complete_request(req.prefix_rid)
        self._drain(req)
        req.finished_at = self.now
        self._trace_req_end(req)
        return True

    def drain(self, req: Request) -> List[int]:
        """Streaming read: materialize every token computed so far (one
        blocking device_get) and return the visible generation. Safe at
        any step; with EOS detection on, tokens past the first EOS are
        not shown."""
        self._drain(req)
        gen = req.generated
        if self.eos_id >= 0 and self.eos_id in gen:
            gen = gen[:gen.index(self.eos_id) + 1]
        return list(gen)

    # -------------------------------------------------------- cache plumbing
    def _block_nbytes(self) -> int:
        return self.pool.block_nbytes

    def _publish(self, req: Request) -> None:
        """Prefill complete: publish the prompt's KV chain into the store.

        Paged: the chain's blocks already live in pool rows the slot's
        block table names — the payload factory hands the store a shared
        reference to each fresh block's row. Zero dispatches, zero copies.

        Gather: the store makes room first (freeing pool indices O(1)),
        then the factory allocates one pool row per fresh block and a
        single jitted scatter captures exactly those blocks from the
        slot's contiguous cache."""
        if self.paged:
            table = self._tables[req.slot]
            self.store.insert(req.prompt,
                              lambda i, _node: self.pool.share(table[i]),
                              self.pool.block_nbytes)
            return
        fresh: List[Tuple[int, int]] = []       # (chain position, pool row)

        def alloc(i, _node):
            idx = self.pool.alloc()
            fresh.append((i, idx))
            return idx

        self.store.insert(req.prompt, alloc, self.pool.block_nbytes)
        if fresh:
            self.pool.scatter_from(self.cache, req.slot,
                                   [i for i, _ in fresh],
                                   [idx for _, idx in fresh])
            self.transfer_dispatches += 1

    # ---------------------------------------------------------------- admit
    def _admit(self) -> None:
        bt = self.store.block_tokens
        for i in range(self.B):
            if self.slots[i] is not None or not self.queue:
                continue
            queued = len(self.queue)
            if any(r.not_before > self.now for r in self.queue):
                # failover re-admissions wait out their backoff; everyone
                # else competes normally. This branch is unreachable
                # without a crash (not_before defaults to 0.0).
                eligible = [r for r in self.queue
                            if r.not_before <= self.now]
                if not eligible:
                    break
                pick = self.scheduler.admit_idx(eligible)
                req = eligible[pick]
                self.queue.remove(req)
            else:
                pick = self.scheduler.admit_idx(self.queue)
                if pick == 0:
                    req = self.queue.popleft()
                else:
                    req = self.queue[pick]
                    del self.queue[pick]
            self._fresh_slots.add(i)
            usable = self.store.lookup(req.prompt)
            if not self.restore_prefix:
                usable = []             # hit metrics recorded; no restore
            restored = len(usable) * bt
            # the last prompt token is always recomputed: its logits seed
            # generation and were never cached (vLLM does the same)
            restored = min(restored, len(req.prompt) - 1)
            if self.paged:
                # prefix hit = a host-side block-table write: the slot
                # reads the store's rows in place (refcounted shares)
                table = [self.pool.share(n.payload) for n in usable]
                if table and restored < len(table) * bt:
                    # fully-resident chain: the final block must absorb
                    # the recomputed last prompt token — copy-on-write so
                    # the store's row stays pristine
                    priv = self.pool.alloc()
                    self.pool.copy_row(table[-1], priv)
                    self.pool.free(table[-1])
                    table[-1] = priv
                    self.transfer_dispatches += 1
                # private tail rows for the rest of the prompt + decode
                horizon = min(len(req.prompt) + req.max_new, self.max_seq)
                while len(table) * bt < horizon:
                    table.append(self.pool.alloc())
                self._tables[i] = table
                self._tables_dirty = True
            elif usable:
                # jitted gather pool→slot: the whole resident chain lands
                # in one dispatch, no host round-trip
                self.cache = self.pool.gather_into(
                    self.cache, i, [n.payload for n in usable])
                self.transfer_dispatches += 1
            req.slot = i
            req.pos = restored
            req.prefill_skipped = restored
            self.prefill_tokens_skipped += restored
            self.slots[i] = req
            if self.trace is not None:
                self.trace.instant(
                    "sched.admit", "sched", self._trace_pid, _TID_SCHED,
                    args={"rid": req.rid, "slot": i, "pick": pick,
                          "queued": queued, "restored_tokens": restored})
                self.trace.async_instant(
                    "req", self._aid(req), "request", self._trace_pid,
                    _TID_REQ, args={"event": "admitted", "slot": i,
                                    "restored_tokens": restored})

    # ----------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One engine iteration. Decode slots pack first (one pipelined
        token each); the scheduler then divides this step's prefill work —
        up to ``prefill_chunk`` tokens per prefilling slot under FCFS, a
        deadline-ordered token budget under the budgeted scheduler (slots
        it preempts idle for the step) — all in a single batched dispatch.
        Returns requests that finished."""
        trace = self.trace
        if trace is None:
            return self._step_inner(None)
        trace.vt = self.now
        with trace.span("step", "engine", self._trace_pid, _TID_ENGINE,
                        args={"n": self.steps}):
            return self._step_inner(trace)

    def _step_inner(self, trace) -> List[Request]:
        pid = self._trace_pid
        if trace is None:
            self._admit()
        else:
            with trace.span("admit", "engine", pid, _TID_ENGINE):
                self._admit()
        active = [r for r in self.slots if r is not None]
        if not active:
            if self.queue and all(r.not_before > self.now
                                  for r in self.queue):
                # everything queued is backing off: jump the virtual clock
                # to the earliest re-admission so the loop can't spin
                self.now = min(r.not_before for r in self.queue)
            return []
        decoding = [r for r in active if r.pos >= len(r.prompt)]
        prefilling = [r for r in active if r.pos < len(r.prompt)]
        plan = self.scheduler.plan_prefill(prefilling, self.prefill_chunk,
                                           len(decoding))
        plan = {s: n for s, n in plan.items() if n > 0}
        if not decoding and not plan and prefilling:
            # never stall a step that has only prefill work: feed the
            # scheduler's most urgent slot its chunk (a zero budget means
            # "prefill only when decode is idle", not "never prefill")
            r = prefilling[0]
            plan = {r.slot: min(self.prefill_chunk,
                                len(r.prompt) - r.pos)}
        if trace is not None:
            trace.instant(
                "sched.plan", "sched", pid, _TID_SCHED,
                args={"plan": {str(s): n for s, n in plan.items()},
                      "preempted": [r.rid for r in prefilling
                                    if r.slot not in plan],
                      "decoding": len(decoding)})
        dispatch = (trace.span("dispatch", "engine", pid,
                               _TID_ENGINE).begin()
                    if trace is not None else None)
        feeds: Dict[int, List[int]] = {}
        use_prev = np.zeros((self.B,), bool)
        for r in decoding:
            # the feed is the previous step's argmax for this slot —
            # routed on device, never synced to host
            feeds[r.slot] = [0]
            use_prev[r.slot] = True
            self.decoded_tokens += 1
        for r in prefilling:
            n = plan.get(r.slot, 0)
            if n:                      # preempted slots idle this step
                feeds[r.slot] = r.prompt[r.pos:r.pos + n]
                self.prefill_tokens += n
        fed = [r for r in active if r.slot in feeds]
        S = max(len(f) for f in feeds.values())
        tokens = np.zeros((self.B, S), np.int32)
        # meta rows: pos / lens / use_prev / emits-generated / reset-done
        meta = np.zeros((5, self.B), np.int32)
        meta[2] = use_prev
        for r in fed:
            f = feeds[r.slot]
            tokens[r.slot, :len(f)] = f
            meta[0, r.slot] = r.pos
            meta[1, r.slot] = len(f)
            meta[3, r.slot] = r.pos + len(f) >= len(r.prompt)
        for i in self._fresh_slots:
            meta[4, i] = 1
        self._fresh_slots.clear()
        args = (self.params,
                self.pool.buffers if self.paged else self.cache,
                self._put(tokens), self._put(meta))
        if self.paged:
            if self._tables_dirty:
                # attention (and the per-layer page gather on the XLA
                # path) costs scale with the widest ACTIVE table, not
                # max_seq — block granularity's other dividend. Bucketed
                # to multiples of 4 so the jit specializations stay few.
                nw = max((len(t) for t in self._tables), default=1)
                nw = min(self.table_width, max(-(-max(nw, 1) // 4) * 4, 4))
                tables = np.zeros((self.B, nw), np.int32)
                for r in active:
                    tab = self._tables[r.slot]
                    tables[r.slot, :len(tab)] = tab
                self._tables_dev = self._put(tables)
                self._tables_dirty = False
            args += (self._tables_dev,)
        args += (self._prev_out, self._done_dev)
        # shapes/shardings of this dispatch, captured BEFORE the call
        # (donation invalidates the KV buffers) — step_hlo() re-lowers
        # from these to expose the compiled step, collectives included
        self._last_step_avals = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                a.shape, a.dtype, sharding=getattr(a, "sharding", None)),
            args)
        out_tok, new_kv, self._done_dev = self._step(*args)
        if self.paged:
            self.pool.buffers = new_kv
        else:
            self.cache = new_kv
        self._prev_out = out_tok
        if dispatch is not None:
            dispatch.end(args={"S": S, "fed": len(fed),
                               "decoding": len(decoding)})
        self.steps += 1
        # prefill attention reads this step: a prompt chunk of ``lens``
        # tokens attends over a context ending at pos + lens, so late
        # chunks of a long prompt are the expensive ones (decode-side
        # attention is memory-bound and folded into per_token)
        pre = (meta[2] == 0) & (meta[1] > 0)
        attn_pairs = int((meta[1] * (meta[0] + meta[1]) * pre).sum())
        self.now += float(self.clock(int(meta[1].sum()) - len(decoding),
                                     len(decoding), attn_pairs))
        stall = getattr(self.store, "pending_stall", 0.0)
        if stall:
            # slow promotions this step (injected disk stalls) charge the
            # virtual clock once, after the step's compute charge
            self.now += stall
            self.store.pending_stall = 0.0
        if trace is not None:
            trace.vt = self.now
            trace.counter("engine", pid, {
                "queue": len(self.queue),
                "active_slots": sum(s is not None for s in self.slots),
                "pool_blocks_in_use": self.pool.blocks_in_use,
                "store_used_bytes": self.store.used})

        finished: List[Request] = []
        for r in fed:
            r.pos += len(feeds[r.slot])
            in_decode = r.pos >= len(r.prompt)
            if in_decode:
                r.n_generated += 1
                r._lazy_out.append(out_tok)
                if r.n_generated == 1:
                    r.first_token_at = self.now
                    if trace is not None:
                        trace.async_instant(
                            "req", self._aid(r), "request", pid, _TID_REQ,
                            args={"event": "first_token"})
            if r.pos == len(r.prompt):
                self._publish(r)
            if in_decode and r.n_generated >= r.max_new:
                self._finish(r)
                finished.append(r)
        if self.eos_id >= 0 and decoding \
                and self.steps % self.eos_interval == 0:
            # device-side EOS detection: one (B,) bool sync per interval
            # instead of the whole token vector every step. A slot that
            # hit EOS between checks decoded a few garbage tokens past it
            # — _finish truncates them — in exchange for pipelined steps.
            if trace is None:
                done = np.asarray(jax.device_get(self._done_dev))
            else:
                with trace.span("eos_sync", "engine", pid, _TID_ENGINE):
                    done = np.asarray(jax.device_get(self._done_dev))
            self.readback_syncs += 1
            for r in decoding:
                if not r.done and done[r.slot]:
                    self._finish(r)
                    finished.append(r)
        return finished

    def _finish(self, r: Request) -> None:
        """Complete a request: drain pipelined tokens, truncate at the
        first EOS, retire the store chain, release the slot."""
        self._drain(r)
        if self.eos_id >= 0 and self.eos_id in r.generated:
            r.generated = r.generated[:r.generated.index(self.eos_id) + 1]
        r.n_generated = len(r.generated)
        r.done = True
        r.finished_at = self.now
        self.store.complete_request(r.prefix_rid)
        self._release_slot(r)
        self._trace_req_end(r)

    def _release_slot(self, r: Request) -> None:
        """Free a slot's engine-side resources *now* (finish or cancel):
        on the paged plane every block-table row drops the slot's
        reference — private tail rows return to the pool immediately,
        store-shared rows survive on the store's own reference."""
        if self.paged:
            for idx in self._tables[r.slot]:
                self.pool.free(idx)
            self._tables[r.slot] = []
            self._tables_dirty = True
        self.slots[r.slot] = None

    def _drain(self, r: Request) -> None:
        """Drain a request's pipelined token reads into ``generated`` (one
        blocking device_get for all of them — by finish time the pipeline
        has usually already computed every step)."""
        if r._lazy_out:
            if self.trace is None:
                vals = jax.device_get(r._lazy_out)
            else:
                with self.trace.span("readback", "engine", self._trace_pid,
                                     _TID_ENGINE,
                                     args={"steps": len(r._lazy_out),
                                           "rid": r.rid}):
                    vals = jax.device_get(r._lazy_out)
            r.generated.extend(int(v[r.slot]) for v in vals)
            r._lazy_out = []
            self.readback_syncs += 1

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step()

    def close(self) -> None:
        """Deterministic teardown of file-backed store resources (the
        disk tier's memmap row files). Idempotent; safe on stores with
        no disk tier."""
        close = getattr(self.store, "close", None)
        if close is not None:
            close()

    def step_hlo(self) -> str:
        """Compiled-HLO text of the most recent step dispatch (re-lowered
        from its captured shapes/shardings — the donated buffers
        themselves are gone). Lets benches count the collectives a TP
        step actually issues. Requires at least one step() call."""
        if self._last_step_avals is None:
            raise RuntimeError("step_hlo() needs a prior step()")
        return self._step.lower(*self._last_step_avals).compile().as_text()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        m = dict(self.store.metrics())
        m.update({
            "engine_steps": self.steps,
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "decoded_tokens": self.decoded_tokens,
            "pool_blocks": self.pool.num_blocks,
            "pool_blocks_in_use": self.pool.blocks_in_use,
            "pool_high_water": self.pool.high_water,
            "kv_transfer_dispatches": self.transfer_dispatches,
            "readback_syncs": self.readback_syncs,
            "virtual_time": self.now,
            "rejected": self.rejected,
            "cancellations": self.cancellations,
            "host_syncs_avoided": max(self.steps - self.readback_syncs, 0),
            # per-device vs global KV bytes, split EXPLICITLY: once the
            # pool shards (tp>1) the two differ by a factor of tp, and
            # "device_kv_bytes" keeps meaning what it says — bytes ONE
            # device holds. (The gather cache only exists at tp=1.)
            "serve_tp": self.tp,
            "device_kv_bytes": self.pool.nbytes_per_device + (
                0 if self.cache is None else
                sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))),
            "kv_bytes_global": self.pool.nbytes + (
                0 if self.cache is None else
                sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))),
            "prefill_saved_frac": (
                self.prefill_tokens_skipped
                / max(self.prefill_tokens + self.prefill_tokens_skipped, 1)),
        })
        if isinstance(self.store, TieredKVStore) \
                and self.store.host_pool is not None:
            hp = self.store.host_pool
            m.update({
                "host_blocks": hp.num_blocks,
                "host_blocks_in_use": hp.blocks_in_use,
                "host_high_water": hp.high_water,
            })
            if self.store.quant is not None:
                # per-tier occupancy in BYTES + the transcode economics:
                # how many blocks one host byte buys vs the lossless tier
                m.update({
                    "kv_quant": self.store.quant.name,
                    "host_block_nbytes": hp.block_nbytes,
                    "host_bytes_in_use": hp.bytes_in_use,
                    "host_compression_ratio": (
                        self.pool.block_nbytes / max(hp.block_nbytes, 1)),
                })
            dp = self.store.disk_pool
            if dp is not None:
                m.update({
                    "disk_blocks": dp.num_blocks,
                    "disk_blocks_in_use": dp.blocks_in_use,
                    "disk_high_water": dp.high_water,
                    "disk_block_nbytes": dp.block_nbytes,
                    "disk_bytes_in_use": dp.bytes_in_use,
                })
        return m

"""Continuous-batching serve engine: chunked prefill over a device-resident
paged KV pool, with a LERC prefix cache underneath.

The serving data plane (PR 2) is built so the hot path is dominated by
real compute, not Python-loop and PCIe overhead — the regime where the
paper's claim (coordinated caching speeds up *jobs*) is measurable:

* **Chunked prefill** — each engine step feeds up to ``prefill_chunk``
  prompt tokens per slot through one batched ``decode_step`` (per-slot
  scatter writes in ``layers.attention`` handle ``Sq > 1`` chunks at
  per-slot offsets), so a P-token prompt costs ~ceil(P/chunk) dispatches
  instead of ~P. Prefill-chunk slots and decode slots share the dispatch;
  decode rows are right-padded and masked.
* **Paged KV pool** — prefix-cache payloads are indices into a
  preallocated per-leaf device pool (``serve.kv_pool.KVBlockPool``). A hit
  is a jitted gather pool→slot, an insert a jitted scatter slot→pool of
  exactly the fresh blocks, and an eviction frees one index — zero
  host↔device KV copies anywhere on the hit/insert path.

Store-visible behavior (the sequence of ``register_request`` / ``lookup``
/ ``insert`` / ``complete_request`` calls and therefore every eviction
decision) is unchanged from the legacy engine on workloads with uniform
prompt/generation lengths; ``tests/test_engine_equivalence.py`` proves
token-identical generations and bit-identical eviction logs against both
``LegacyServeEngine`` and the brute-force ``ReferencePrefixStore``.

The engine supports uniform global-attention patterns (every cache leaf a
KV buffer indexed by absolute position) — smoke-scale configs serve as
the integration testbed; the store itself is payload-agnostic.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_decode_cache
from ..models.common import ModelConfig
from .host_pool import HostBlockPool
from .kv_pool import KVBlockPool, chain_block_nbytes
from .prefix_store import PrefixStore
from .tiered import TieredKVStore

# pool rows a default-constructed engine starts with when the store's byte
# budget is effectively unbounded (the pool doubles on demand)
_DEFAULT_POOL_BLOCKS = 256


@lru_cache(maxsize=None)
def _step_fn(cfg: ModelConfig):
    """One shared jitted step per (hashable) config: engines spun up on the
    same model reuse every compiled (B, S) specialization instead of
    retracing behind a fresh closure."""

    def _step(p, c, t, pos, lens):
        logits, new_cache = decode_step(cfg, p, c, t, pos, seq_lens=lens)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), \
            new_cache

    return jax.jit(_step)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    prefix_rid: int = -1            # id inside the PrefixStore
    slot: int = -1
    pos: int = 0                    # next position to fill
    generated: List[int] = field(default_factory=list)
    prefill_skipped: int = 0
    done: bool = False


def _kv_leaves(cache) -> List[Tuple[Tuple[str, ...], jax.Array]]:
    out = []

    def walk(t, path=()):
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, path + (k,))
        else:
            out.append((path, t))

    walk(cache)
    return out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_seq: int = 256, store: Optional[PrefixStore] = None,
                 eos_id: int = -1, prefill_chunk: int = 8,
                 pool_blocks: Optional[int] = None) -> None:
        for path, _ in _kv_leaves(init_decode_cache(cfg, 1, 8)):
            assert path[-1] in ("k", "v"), (
                "ServeEngine supports uniform-KV patterns; got leaf "
                f"{'/'.join(path)}")
        if prefill_chunk > 1:
            kinds = set(cfg.layer_pattern)
            assert kinds <= {"G", "M"}, (
                "chunked prefill needs absolute-position KV caches; "
                f"pattern {cfg.layer_pattern!r} has rolling/recurrent layers")
        self.cfg = cfg
        self.params = params
        self.B = max_slots
        self.max_seq = max_seq
        self.store = store or PrefixStore(capacity_bytes=1 << 62,
                                          policy="lerc")
        self.eos_id = eos_id
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.cache = init_decode_cache(cfg, self.B, max_seq)

        # ----- paged pool: sized so the store's byte budget, not the pool,
        # is always the binding constraint (bounded budgets evict — and
        # free indices — before alloc; unbounded ones rely on growth)
        bt = self.store.block_tokens
        blk_bytes = chain_block_nbytes(self.cache, bt)
        if pool_blocks is None:
            by_capacity = -(-self.store.capacity // max(blk_bytes, 1))
            pool_blocks = int(min(by_capacity, _DEFAULT_POOL_BLOCKS))
        self.pool = KVBlockPool(self.cache, bt, pool_blocks)
        if isinstance(self.store, TieredKVStore):
            # tier 1: host-side pool sized to the store's host byte budget
            # (0 rows when the tier is disabled — the store then behaves
            # op-for-op like a plain PrefixStore)
            self.store.attach_pools(
                self.pool,
                HostBlockPool.for_device_pool(self.cache, self.pool,
                                              self.store.host_capacity))
        else:
            self.store.evict_payload = self.pool.free

        self._step_fn = _step_fn(cfg)
        self._rid = itertools.count(1)
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * self.B
        self.steps = 0
        self.decoded_tokens = 0
        self.prefill_tokens = 0
        self.prefill_tokens_skipped = 0

    # ------------------------------------------------------------- requests
    def submit(self, prompt: Sequence[int], max_new: int = 16) -> Request:
        req = Request(next(self._rid), list(prompt), max_new)
        req.prefix_rid = self.store.register_request(prompt)
        self.queue.append(req)
        return req

    # -------------------------------------------------------- cache plumbing
    def _block_nbytes(self) -> int:
        return self.pool.block_nbytes

    def _publish(self, req: Request) -> None:
        """Prefill complete: publish the prompt's KV chain into the pool.
        The store makes room first (freeing pool indices O(1), no copies),
        then the factory allocates one pool row per *fresh* block; a single
        jitted scatter captures exactly those blocks from the slot."""
        fresh: List[Tuple[int, int]] = []       # (chain position, pool row)

        def alloc(i, _node):
            idx = self.pool.alloc()
            fresh.append((i, idx))
            return idx

        self.store.insert(req.prompt, alloc, self.pool.block_nbytes)
        if fresh:
            self.pool.scatter_from(self.cache, req.slot,
                                   [i for i, _ in fresh],
                                   [idx for _, idx in fresh])

    # ---------------------------------------------------------------- admit
    def _admit(self) -> None:
        for i in range(self.B):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            usable = self.store.lookup(req.prompt)
            restored = 0
            if usable:
                # jitted gather pool→slot: the whole resident chain lands
                # in one dispatch, no host round-trip
                self.cache = self.pool.gather_into(
                    self.cache, i, [n.payload for n in usable])
                restored = len(usable) * self.store.block_tokens
            # the last prompt token is always recomputed: its logits seed
            # generation and were never cached (vLLM does the same)
            restored = min(restored, len(req.prompt) - 1)
            req.slot = i
            req.pos = restored
            req.prefill_skipped = restored
            self.prefill_tokens_skipped += restored
            self.slots[i] = req

    # ----------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One engine iteration — up to ``prefill_chunk`` prompt tokens per
        prefilling slot, one token per decoding slot, all in a single
        batched dispatch. Returns requests that finished."""
        self._admit()
        active = [r for r in self.slots if r is not None]
        if not active:
            return []
        feeds: Dict[int, List[int]] = {}
        for r in active:
            if r.pos < len(r.prompt):                  # prefill phase
                n = min(self.prefill_chunk, len(r.prompt) - r.pos)
                feeds[r.slot] = r.prompt[r.pos:r.pos + n]
                self.prefill_tokens += n
            else:                                      # decode phase
                feeds[r.slot] = [r.generated[-1] if r.generated
                                 else r.prompt[-1]]
                self.decoded_tokens += 1
        S = max(len(f) for f in feeds.values())
        tokens = np.zeros((self.B, S), np.int32)
        pos = np.zeros((self.B,), np.int32)
        lens = np.zeros((self.B,), np.int32)
        for r in active:
            f = feeds[r.slot]
            tokens[r.slot, :len(f)] = f
            pos[r.slot] = r.pos
            lens[r.slot] = len(f)
        out_tok, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos),
            jnp.asarray(lens))
        out = np.asarray(out_tok)
        self.steps += 1

        finished: List[Request] = []
        for r in active:
            r.pos += len(feeds[r.slot])
            in_decode = r.pos >= len(r.prompt)
            if in_decode:
                r.generated.append(int(out[r.slot]))
            if r.pos == len(r.prompt):
                self._publish(r)
            if in_decode and (len(r.generated) >= r.max_new
                              or (self.eos_id >= 0
                                  and r.generated[-1] == self.eos_id)):
                r.done = True
                finished.append(r)
                self.store.complete_request(r.prefix_rid)
                self.slots[r.slot] = None
        return finished

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        m = dict(self.store.metrics())
        m.update({
            "engine_steps": self.steps,
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "decoded_tokens": self.decoded_tokens,
            "pool_blocks": self.pool.num_blocks,
            "pool_blocks_in_use": self.pool.blocks_in_use,
            "pool_high_water": self.pool.high_water,
            "prefill_saved_frac": (
                self.prefill_tokens_skipped
                / max(self.prefill_tokens + self.prefill_tokens_skipped, 1)),
        })
        if isinstance(self.store, TieredKVStore) \
                and self.store.host_pool is not None:
            hp = self.store.host_pool
            m.update({
                "host_blocks": hp.num_blocks,
                "host_blocks_in_use": hp.blocks_in_use,
                "host_high_water": hp.high_water,
            })
        return m

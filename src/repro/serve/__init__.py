"""repro.serve — continuous-batching engine over a DAG-aware radix prefix
cache (the paper's all-or-nothing property on KV block chains), sharing
the core eviction substrate (DagState counters + EvictionIndex). The
default data plane is zero-copy paged attention: ``KVBlockPool`` is the
only KV storage, slots own refcounted block tables, prefix hits are
host-side table writes and decode streams straight out of the pool
(Pallas paged flash-decoding on TPU); a gather/scatter plane remains as
the fallback for non-absolute-position layer patterns, and chunked
prefill rides both. ``TieredKVStore`` + ``HostBlockPool`` +
``DiskBlockPool`` add core's tiered semantics three rungs deep:
device-pressure victims demote to a host-memory tier (optionally
transcoded to int8/fp8 via ``repro.quant`` so the budget holds more
complete chains per byte), host-pressure victims demote again to a
file-backed disk tier, and demoted chains promote back on reuse instead
of being recomputed.
The front door (PR 6) makes the tier always-on: ``scheduler`` policies
({fcfs, decode-first, budgeted}) divide each step's prefill work against
decode latency, ``play_trace`` drives an engine or frontend from a timed
arrival trace with admission control (``QueueFull`` backpressure) and
per-request deadlines, and ``latency_stats`` reports TTFT/TPOT
percentiles + goodput-under-deadline on the deterministic virtual clock
(``StepCostModel``). ``LegacyServeEngine`` and ``ReferencePrefixStore``
are the frozen pre-optimization baselines the equivalence tests and
benchmarks measure against."""
from .disk_pool import DiskBlockPool
from .engine import Request, ServeEngine
from .host_pool import HostBlockPool
from .kv_pool import KVBlockPool
from .legacy import LegacyServeEngine
from .prefix_store import Node, PrefixStore
from .reference import ReferencePrefixStore
from .scheduler import (BudgetedScheduler, DecodeFirstScheduler,
                        FCFSScheduler, QueueFull, Scheduler, StepCostModel,
                        TracedRequest, TraceReport, latency_stats,
                        make_scheduler, play_trace)
from .sharded import ShardedFrontend, route_prefix
from .tiered import TieredKVStore

__all__ = ["Request", "ServeEngine", "LegacyServeEngine", "KVBlockPool",
           "HostBlockPool", "DiskBlockPool", "Node", "PrefixStore",
           "ReferencePrefixStore",
           "ShardedFrontend", "TieredKVStore", "route_prefix",
           "Scheduler", "FCFSScheduler", "DecodeFirstScheduler",
           "BudgetedScheduler", "make_scheduler", "StepCostModel",
           "QueueFull", "TracedRequest", "TraceReport", "play_trace",
           "latency_stats"]

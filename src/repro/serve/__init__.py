"""repro.serve — continuous-batching engine (chunked prefill, paged
device-resident KV pool) over a DAG-aware radix prefix cache (the paper's
all-or-nothing property on KV block chains), sharing the core eviction
substrate (DagState counters + EvictionIndex). ``TieredKVStore`` +
``HostBlockPool`` add core's two-tier semantics to the data plane:
device-pressure victims demote to a host-memory tier and promote back on
reuse instead of being recomputed. ``LegacyServeEngine`` and
``ReferencePrefixStore`` are the frozen pre-optimization baselines the
equivalence tests and benchmarks measure against."""
from .engine import Request, ServeEngine
from .host_pool import HostBlockPool
from .kv_pool import KVBlockPool
from .legacy import LegacyServeEngine
from .prefix_store import Node, PrefixStore
from .reference import ReferencePrefixStore
from .sharded import ShardedFrontend, route_prefix
from .tiered import TieredKVStore

__all__ = ["Request", "ServeEngine", "LegacyServeEngine", "KVBlockPool",
           "HostBlockPool", "Node", "PrefixStore", "ReferencePrefixStore",
           "ShardedFrontend", "TieredKVStore", "route_prefix"]

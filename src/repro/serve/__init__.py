"""repro.serve — continuous-batching engine (chunked prefill, paged
device-resident KV pool) over a DAG-aware radix prefix cache (the paper's
all-or-nothing property on KV block chains), sharing the core eviction
substrate (DagState counters + EvictionIndex). ``LegacyServeEngine`` and
``ReferencePrefixStore`` are the frozen pre-optimization baselines the
equivalence tests and benchmarks measure against."""
from .engine import Request, ServeEngine
from .kv_pool import KVBlockPool
from .legacy import LegacyServeEngine
from .prefix_store import Node, PrefixStore
from .reference import ReferencePrefixStore
from .sharded import ShardedFrontend, route_prefix

__all__ = ["Request", "ServeEngine", "LegacyServeEngine", "KVBlockPool",
           "Node", "PrefixStore", "ReferencePrefixStore", "ShardedFrontend",
           "route_prefix"]

"""repro.serve — continuous-batching engine over a DAG-aware radix prefix
cache (the paper's all-or-nothing property on KV block chains), sharing
the core eviction substrate (DagState counters + EvictionIndex)."""
from .engine import Request, ServeEngine
from .prefix_store import Node, PrefixStore
from .reference import ReferencePrefixStore

__all__ = ["Request", "ServeEngine", "Node", "PrefixStore",
           "ReferencePrefixStore"]

"""repro.serve — continuous-batching engine over a LERC-evicted radix
prefix cache (the paper's all-or-nothing property on KV block chains)."""
from .engine import Request, ServeEngine
from .prefix_store import Node, PrefixStore

__all__ = ["Request", "ServeEngine", "Node", "PrefixStore"]

"""The pre-pool serve engine, frozen as a measured baseline.

Token-at-a-time scheduling (ONE prompt token per jitted dispatch per
slot) with host-resident KV payloads: every prefix-cache hit copies all
chain blocks host→device (``_copy_chain_in``) and every insert copies
slot KV device→host (``_extract_blocks``). ``serve.engine.ServeEngine``
replaces both hot paths (chunked prefill + device-resident block pool);
this module is kept — like ``serve.reference`` for the store — so the
equivalence tests can prove token-identical generations / identical
eviction decisions and ``benchmarks/serve_throughput.py`` can measure the
old-vs-new gap on the same workload. Do not optimize this file.
"""
from __future__ import annotations

import itertools
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_decode_cache
from ..models.common import ModelConfig
from .engine import Request, _kv_leaves
from .prefix_store import PrefixStore


@lru_cache(maxsize=None)
def _legacy_step_fn(cfg: ModelConfig):
    """Shared per-config jitted step (compile once across engine
    instances — keeps the baseline's measured window compile-free too)."""

    def _step(p, c, t, pos):
        logits, new_cache = decode_step(cfg, p, c, t, pos)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), \
            new_cache

    return jax.jit(_step)


class LegacyServeEngine:
    """Seed-era engine: per-token prefill, host KV round-trips."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_seq: int = 256, store: Optional[PrefixStore] = None,
                 eos_id: int = -1) -> None:
        for path, _ in _kv_leaves(init_decode_cache(cfg, 1, 8)):
            assert path[-1] in ("k", "v"), (
                "LegacyServeEngine supports uniform-KV patterns; got leaf "
                f"{'/'.join(path)}")
        self.cfg = cfg
        self.params = params
        self.B = max_slots
        self.max_seq = max_seq
        self.store = store or PrefixStore(capacity_bytes=1 << 62,
                                          policy="lerc")
        self.eos_id = eos_id
        self.cache = init_decode_cache(cfg, self.B, max_seq)
        self._step_fn = _legacy_step_fn(cfg)
        self._rid = itertools.count(1)
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * self.B
        self.steps = 0
        self.decoded_tokens = 0
        self.prefill_tokens = 0
        self.prefill_tokens_skipped = 0

    # ------------------------------------------------------------- requests
    def submit(self, prompt: Sequence[int], max_new: int = 16) -> Request:
        req = Request(next(self._rid), list(prompt), max_new)
        req.prefix_rid = self.store.register_request(prompt)
        self.queue.append(req)
        return req

    # -------------------------------------------------------- cache plumbing
    def _copy_chain_in(self, slot: int, payloads: List[Dict]) -> int:
        """Write resident chain payloads into the slot cache; returns the
        number of prefix tokens restored (host→device copy)."""
        if not payloads:
            return 0
        bt = self.store.block_tokens
        per_leaf: Dict[Tuple[str, ...], List[np.ndarray]] = {}
        for payload in payloads:
            for path, arr in payload.items():
                per_leaf.setdefault(path, []).append(np.asarray(arr))
        n_tok = len(payloads) * bt
        for path, blocks in per_leaf.items():
            chain = jnp.asarray(np.concatenate(blocks, axis=-3))
            leaf = self._leaf(path)
            self._set_leaf(path,
                           leaf.at[..., slot, 0:n_tok, :, :].set(chain))
        return n_tok

    def _leaf(self, path):
        node = self.cache
        for p in path:
            node = node[p]
        return node

    def _set_leaf(self, path, value) -> None:
        node = self.cache
        for p in path[:-1]:
            node = node[p]
        node[path[-1]] = value

    def _extract_blocks(self, slot: int, n_tokens: int) -> List[Dict]:
        """Read KV payloads for the first n_tokens of ``slot``, one dict
        per full block (device→host copy)."""
        bt = self.store.block_tokens
        n_blocks = n_tokens // bt
        payloads: List[Dict] = []
        leaves = _kv_leaves(self.cache)
        for j in range(n_blocks):
            t0 = j * bt
            payloads.append({
                path: np.asarray(arr[..., slot, t0:t0 + bt, :, :])
                for path, arr in leaves})
        return payloads

    def _block_nbytes(self) -> int:
        bt = self.store.block_tokens
        total = 0
        for _, arr in _kv_leaves(self.cache):
            per_tok = arr.nbytes // (arr.shape[-3] * self.B)
            total += per_tok * bt
        return total

    # ---------------------------------------------------------------- admit
    def _admit(self) -> None:
        for i in range(self.B):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            usable = self.store.lookup(req.prompt)
            payloads = [n.payload for n in usable]
            restored = self._copy_chain_in(i, payloads) if payloads else 0
            # the last prompt token is always recomputed: its logits seed
            # generation and were never cached (vLLM does the same)
            restored = min(restored, len(req.prompt) - 1)
            req.slot = i
            req.pos = restored
            req.prefill_skipped = restored
            self.prefill_tokens_skipped += restored
            self.slots[i] = req

    # ----------------------------------------------------------------- step
    def step(self) -> List[Request]:
        """One engine iteration; returns requests that finished."""
        self._admit()
        active = [r for r in self.slots if r is not None]
        if not active:
            return []
        tokens = np.zeros((self.B, 1), np.int32)
        pos = np.zeros((self.B,), np.int32)
        for r in active:
            if r.pos < len(r.prompt):                  # prefill phase
                tokens[r.slot, 0] = r.prompt[r.pos]
                self.prefill_tokens += 1
            else:                                      # decode phase
                tokens[r.slot, 0] = (r.generated[-1] if r.generated
                                     else r.prompt[-1])
                self.decoded_tokens += 1
            pos[r.slot] = r.pos
        out_tok, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos))
        out = np.asarray(out_tok)
        self.steps += 1

        finished: List[Request] = []
        for r in active:
            r.pos += 1
            in_decode = r.pos >= len(r.prompt)
            if in_decode:
                tok = int(out[r.slot, 0] if out.ndim == 2
                          else out[r.slot])
                r.generated.append(tok)
            if r.pos == len(r.prompt):
                # prefill complete: publish the prompt's KV chain
                n_pub = len(r.prompt)
                self.store.insert(r.prompt,
                                  self._extract_blocks(r.slot, n_pub),
                                  self._block_nbytes())
            if in_decode and (len(r.generated) >= r.max_new
                              or (self.eos_id >= 0
                                  and r.generated[-1] == self.eos_id)):
                r.done = True
                finished.append(r)
                self.store.complete_request(r.prefix_rid)
                self.slots[r.slot] = None
        return finished

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step()

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        m = dict(self.store.metrics())
        m.update({
            "engine_steps": self.steps,
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "decoded_tokens": self.decoded_tokens,
            "prefill_saved_frac": (
                self.prefill_tokens_skipped
                / max(self.prefill_tokens + self.prefill_tokens_skipped, 1)),
        })
        return m

"""Radix prefix cache with DAG-aware eviction — the paper's idea, 8 years
later, running on the paper's own machinery.

A served request hits the KV prefix cache only if **every** block along
its prefix chain is resident: a resident block whose ancestor was evicted
is useless (prefill must restart at the first gap). That is precisely the
paper's all-or-nothing property with peer groups generalized to *chains*,
and this store is now a thin client of the same incremental substrate the
batch layer uses (``core.DagState`` + ``core.EvictionIndex``), instead of
re-deriving reference counts from scratch on every eviction.

The chain→peer-group adapter: a pending request r with chain n1→…→nk
contributes one *task* per chain position i, whose peer group is the
ancestor set {n1…ni} and whose (virtual) output is never materialized
while r is pending. Under the paper's Definitions this yields, per the
shared incremental counters:

* ``ref_count[b]``     = Σ over pending chains of the positions at or
  below b — a *depth-weighted* reference count (an ancestor is worth at
  least as much as any of its descendants);
* ``eff_ref_count[b]`` = the same sum restricted to positions whose whole
  prefix is resident (Def. 2, chain form).

The old "deepest-first on ties" rule survives in two parts: while a chain
is referenced, depth-weighting orders it automatically (a leaf's (erc, rc)
is ≤ its parent's on the same chain); once a chain has no pending
references, the leaf→root clock stamping in ``lookup``/``insert`` makes
recency ties evict leaves before ancestors. Either way, evicting a victim
never orphans resident descendants.

Every ``core`` policy (lru/mru/fifo/lfu/lrc/lerc/sticky/belady) is
available via ``make_policy``; metrics are ``core.metrics.CacheMetrics``.
Victim selection is O(log n) heap pops against incrementally-maintained
counters; the retained brute-force oracle lives in ``serve.reference`` and
the equivalence tests prove identical eviction decisions.

Payloads are opaque to the store. The pooled engine stores *indices into a
device-resident KV block pool* (``serve.kv_pool``) so eviction is O(1)
index-freeing with zero copies; the legacy host-payload engine stores
per-block KV arrays. ``insert`` optionally takes a payload *factory*
(called only for blocks that actually become resident, after room has
been made), and ``evict_payload`` lets the pool reclaim a victim's block
index the moment it is evicted.

Skeleton GC: ``complete_request`` prunes chain nodes that are neither
resident nor referenced by any pending request, removing their DAG blocks
and counter entries — under sustained traffic the radix tree tracks the
live working set instead of growing with request history.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

from ..core import (BlockMeta, CacheMetrics, DagState, EvictionIndex,
                    JobDAG, Policy, TaskSpec, make_policy)
from ..obs.trace import TID_STORE as _TID_STORE

TokenBlock = Tuple[int, ...]


@dataclass
class Node:
    key: TokenBlock                      # the tokens of this block
    parent: Optional["Node"]
    payload: Any = None                  # per-layer KV arrays (host)
    nbytes: int = 0
    resident: bool = False               # in the FAST tier (device pool)
    # slow-tier payloads (serve.TieredKVStore: a HostBlockPool row / a
    # DiskBlockPool row). Always None in a plain single-tier store; a node
    # holds at most one tier.
    host_payload: Any = None
    disk_payload: Any = None
    # has this node EVER held a fast-tier payload? Distinguishes an
    # "evicted" gap (the policy killed it) from a "never_cached" one
    # (cold chain) when attributing ineffective hits.
    ever_resident: bool = False
    children: Dict[TokenBlock, "Node"] = field(default_factory=dict)
    uid: int = 0

    @property
    def block_id(self) -> str:
        return f"n{self.uid}"


def blocking_cause(node: Node) -> str:
    """Where a non-tier-0 chain node currently sits — the attribution
    bucket charged to every ineffective hit it blocks (the first such
    node on a chain is the one the whole suffix waits on)."""
    if node.host_payload is not None:
        return "host"
    if node.disk_payload is not None:
        return "disk"
    return "evicted" if node.ever_resident else "never_cached"


class PrefixStore:
    def __init__(self, capacity_bytes: int,
                 policy: Union[str, Policy] = "lerc",
                 block_tokens: int = 16) -> None:
        self.capacity = capacity_bytes
        self.block_tokens = block_tokens
        # called with a victim's payload on eviction (pool index reclaim)
        self.evict_payload: Optional[Callable[[Any], None]] = None
        # coordination-plane hooks (serve.ShardedFrontend): every store
        # event a peer replica must see, fired inline so the cross-shard
        # event order is exactly the local one.
        #   on_evict(block_id, flipped_groups)  — after each eviction
        #   on_status(event, ident)             — "loaded" / "task_removed"
        #                                         / "forget_block"
        self.on_evict: Optional[Callable[[str, List[str]], None]] = None
        self.on_status: Optional[Callable[[str, str], None]] = None
        # obs: an attached ``repro.obs.TraceRecorder`` (None = every
        # instrumentation site is one predicate — bit-identical behavior)
        self.trace = None
        self.trace_pid = 0
        self.root = Node(key=(), parent=None, resident=True)
        self.used = 0
        self._uids = itertools.count(1)
        self._req_ids = itertools.count(1)
        # the shared substrate: chain nodes are blocks, pending-request
        # prefixes are peer groups, counters update in O(degree) per event
        self.dag = JobDAG()
        self.state = DagState(self.dag)
        self.policy = policy if isinstance(policy, Policy) \
            else make_policy(policy)
        self.index = EvictionIndex(self.policy, self.state)
        self.metrics_obj = CacheMetrics()
        self._nodes: Dict[str, Node] = {}          # block id -> node
        # outstanding (queued/admitted-not-yet-prefilled) request chains
        self._pending: Dict[int, List[Node]] = {}
        self._req_tasks: Dict[int, List[str]] = {}  # rid -> task ids
        self.eviction_log: List[str] = []           # block ids, in order

    # ------------------------------------------------------------ structure
    def _blocks(self, tokens: Sequence[int]) -> List[TokenBlock]:
        bt = self.block_tokens
        return [tuple(tokens[i:i + bt])
                for i in range(0, len(tokens) - len(tokens) % bt, bt)]

    def _walk(self, tokens: Sequence[int], create: bool = False
              ) -> List[Node]:
        """Nodes along the chain for ``tokens`` (existing, or created
        skeleton nodes when ``create``)."""
        chain: List[Node] = []
        node = self.root
        for key in self._blocks(tokens):
            child = node.children.get(key)
            if child is None:
                if not create:
                    break
                child = Node(key=key, parent=node, uid=next(self._uids))
                node.children[key] = child
                # a chain node is always "materialized" (recomputable by
                # prefill); it is cached only while resident
                self.dag.add_block(BlockMeta(id=child.block_id, size=0,
                                             dataset="kv", index=child.uid))
                self.state.on_materialized(child.block_id, into_cache=False)
                self._nodes[child.block_id] = child
            chain.append(child)
            node = child
        return chain

    # ------------------------------------------------------------- requests
    def register_request(self, tokens: Sequence[int]) -> int:
        """Announce a request (queued). Each prefix of its chain becomes a
        live peer group until ``complete_request``. Returns a request id."""
        rid = next(self._req_ids)
        chain = self._walk(tokens, create=True)
        self._pending[rid] = chain
        tids: List[str] = []
        job = f"req{rid}"
        for i in range(len(chain)):
            tid = f"{job}.{i}"
            out = f"out:{tid}"
            self.dag.add_block(BlockMeta(id=out, size=0, dataset="req",
                                         index=i))
            self.dag.add_task(TaskSpec(
                id=tid, inputs=tuple(n.block_id for n in chain[:i + 1]),
                output=out, job=job))
            self.state.on_task_added(tid)
            tids.append(tid)
        self._req_tasks[rid] = tids
        return rid

    def request_profile(self, rid: int) -> Tuple[List[Node], List[TaskSpec]]:
        """The peer-information profile of a registered request: its chain
        nodes and the per-position peer-group tasks. This is what the
        coordination plane broadcasts when the store is one shard of a
        ``serve.ShardedFrontend``."""
        chain = self._pending[rid]
        tasks = [self.dag.tasks[tid] for tid in self._req_tasks[rid]]
        return chain, tasks

    def complete_request(self, rid: int) -> None:
        """Retire a request: its chain's references leave the counters, its
        peer-group tasks are garbage-collected from the DAG, and chain
        nodes left with no residency and no references are pruned."""
        for tid in self._req_tasks.pop(rid, []):
            self.state.on_task_removed(tid)
            self.dag.remove_task(tid, remove_output=True)
            if self.on_status is not None:
                self.on_status("task_removed", tid)
        chain = self._pending.pop(rid, None)
        if chain:
            self._prune_chain(chain)

    def _prune_chain(self, chain: List[Node]) -> None:
        """Leaf→root GC of a retired chain: a node is garbage iff it is
        non-resident, childless, and carries no pending references
        (``ref_count == 0``). Depth-weighted counts are non-increasing with
        depth and a kept child keeps its parent, so the first kept node
        ends the walk."""
        for node in reversed(chain):
            if not self._is_garbage(node):
                break
            self._forget_node(node)

    def _is_garbage(self, node: Node) -> bool:
        """A skeleton node with nothing keeping it alive: not resident in
        any tier, childless, and free of pending references."""
        return (not node.resident and node.host_payload is None
                and node.disk_payload is None
                and not node.children
                and self.state.ref_count.get(node.block_id, 0) == 0)

    def _forget_node(self, node: Node) -> None:
        """Drop one garbage skeleton node (non-resident, childless,
        unreferenced): unlink it, erase its DAG block + counters, and
        announce the GC on the status channel."""
        node.parent.children.pop(node.key, None)
        self._nodes.pop(node.block_id, None)
        self.index.discard(node.block_id)
        self.state.forget_block(node.block_id)
        self.dag.remove_block(node.block_id)
        node.parent = None
        if self.on_status is not None:
            self.on_status("forget_block", node.block_id)

    # ---------------------------------------------------------------- reads
    def lookup(self, tokens: Sequence[int]) -> List[Node]:
        """Longest fully-resident chain from the root (the usable prefix).
        Records per-block hit/effective-hit metrics along the way.

        Policy clocks are stamped leaf→root, so within one lookup an
        ancestor is always *more* recent than its descendants: recency
        ties evict leaves before ancestors (the seed's deepest-first rule,
        now expressed through the shared policy clocks — evicting a leaf
        never orphans resident descendants)."""
        chain = self._walk(tokens)
        usable: List[Node] = []
        touched: List[Node] = []
        broken = False
        cause = None          # first gap's location: the blocking block
        blocking = [] if self.trace is not None else None
        ineff: Dict[str, int] = {}
        for node in chain:
            hit = node.resident
            if not hit:
                broken = True
                if cause is None:
                    cause = blocking_cause(node)
                if blocking is not None:
                    blocking.append((node.uid, blocking_cause(node)))
            self.metrics_obj.record_access(hit=hit,
                                           effective=hit and not broken,
                                           cause=cause)
            if hit:
                if not broken:
                    usable.append(node)
                else:
                    ineff[cause] = ineff.get(cause, 0) + 1
                touched.append(node)
        for node in reversed(touched):            # leaf first, root last
            self.policy.on_access(node.block_id)
        if self.trace is not None:
            self.trace.instant(
                "store.lookup", "store", self.trace_pid, _TID_STORE,
                args={"blocks": len(chain), "usable": len(usable),
                      "broken": broken, "blocking": blocking,
                      "ineffective": ineff})
        return usable

    # --------------------------------------------------------------- writes
    def insert(self, tokens: Sequence[int],
               payloads: Union[List[Any], Callable[[int, Node], Any]],
               nbytes_per_block: int) -> None:
        """Store KV payloads for the chain of ``tokens`` (post-prefill).
        ``payloads`` is either one payload per chain position, or a factory
        ``(position, node) -> payload`` invoked only for blocks that become
        resident — *after* room has been made, so a pool-backed factory
        allocates from indices the evictions just freed.
        Recency/insertion clocks are stamped leaf→root (see ``lookup``)."""
        chain = self._walk(tokens, create=True)
        exclude = {n.block_id for n in chain}
        fresh: List[Node] = []
        if not callable(payloads):
            chain = chain[:len(payloads)]
        for i, node in enumerate(chain):
            if node.resident:
                continue
            self._pre_insert(node)
            self._make_room(nbytes_per_block, exclude=exclude)
            node.payload = (payloads(i, node) if callable(payloads)
                            else payloads[i])
            node.nbytes = nbytes_per_block
            node.resident = True
            node.ever_resident = True
            self.used += nbytes_per_block
            self.state.on_loaded(node.block_id)   # flips prefixes complete
            self.index.add(node.block_id)
            fresh.append(node)
            if self.on_status is not None:
                self.on_status("loaded", node.block_id)
        for node in reversed(fresh):              # leaf first, root last
            self.policy.on_insert(node.block_id)
        if self.trace is not None and fresh:
            self.trace.instant(
                "store.insert", "store", self.trace_pid, _TID_STORE,
                args={"blocks": [n.uid for n in fresh],
                      "nbytes_per_block": nbytes_per_block})

    def _pre_insert(self, node: Node) -> None:
        """Hook: ``node`` (non-resident) is about to be (re)inserted.
        Tiered stores release a superseded slow-tier copy here."""

    # ------------------------------------------------------------- eviction
    def _make_room(self, needed: int, exclude: set) -> None:
        """Pop victims off the index until ``needed`` bytes fit. Each pop
        is O(log n); the state update after each eviction re-keys exactly
        the blocks whose prefixes it broke, so the next pop already sees
        the flip (the per-victim semantics of the paper's protocol)."""
        while self.used + needed > self.capacity:
            victim = self.index.pop_min(exclude=exclude)
            if victim is None:
                return
            self._evict(self._nodes[victim])

    def _evict(self, node: Node) -> None:
        if self.trace is not None:
            # the policy's eviction key at decision time, before the state
            # update invalidates it
            self.trace.instant(
                "store.evict", "store", self.trace_pid, _TID_STORE,
                args={"uid": node.uid, "block": node.block_id, "tier": 0,
                      "key": str(self.policy.eviction_key(node.block_id,
                                                          self.state))})
        node.resident = False
        if self.evict_payload is not None and node.payload is not None:
            self.evict_payload(node.payload)
        node.payload = None
        self.used -= node.nbytes
        node.nbytes = 0
        self.metrics_obj.evictions += 1
        self.eviction_log.append(node.block_id)
        self.index.discard(node.block_id)     # no-op when popped off
        self.policy.on_remove(node.block_id)
        # complete -> incomplete flips of every pending prefix through this
        # node propagate incrementally (the paper's broadcast moment)
        flipped = self.state.on_evicted(node.block_id)
        if self.on_evict is not None:
            self.on_evict(node.block_id, flipped)

    # -------------------------------------------------------------- metrics
    @property
    def evictions(self) -> int:
        return self.metrics_obj.evictions

    def metrics(self) -> Dict[str, float]:
        self.metrics_obj.check_attribution()
        return {**self.metrics_obj.as_dict(), "used_bytes": self.used}

"""Radix prefix cache with LERC eviction — the paper's idea, 8 years later.

A served request hits the KV prefix cache only if **every** block along
its prefix chain is resident: a resident block whose ancestor was evicted
is useless (prefill must restart at the first gap). That is precisely the
paper's all-or-nothing property with peer-groups generalized to *chains*:

* peer group of request r  = the chain of blocks root→leaf(r);
* a reference of block b by request r is EFFECTIVE iff every ancestor of
  b on r's chain is resident (Def. 2, chain form);
* LERC evicts the resident block with the fewest effective references,
  deepest-first on ties (evicting a leaf never breaks another chain).

Baselines for the benchmark: LRU (recency of block touch) and LRC (plain
reference count = #queued requests whose chain contains the block,
resident-ancestors or not).

Payloads are per-block KV arrays (host memory); the engine copies the hit
chain into a device slot at admission, so a longer effective chain is
exactly fewer prefill FLOPs (measured, not simulated).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

TokenBlock = Tuple[int, ...]


@dataclass
class Node:
    key: TokenBlock                      # the tokens of this block
    parent: Optional["Node"]
    payload: Any = None                  # per-layer KV arrays (host)
    nbytes: int = 0
    resident: bool = False
    children: Dict[TokenBlock, "Node"] = field(default_factory=dict)
    last_touch: int = 0
    uid: int = 0

    def depth(self) -> int:
        d, n = 0, self
        while n.parent is not None:
            d, n = d + 1, n.parent
        return d


class PrefixStore:
    def __init__(self, capacity_bytes: int, policy: str = "lerc",
                 block_tokens: int = 16) -> None:
        assert policy in ("lru", "lrc", "lerc")
        self.capacity = capacity_bytes
        self.policy = policy
        self.block_tokens = block_tokens
        self.root = Node(key=(), parent=None, resident=True)
        self.used = 0
        self._clock = itertools.count(1)
        self._uids = itertools.count(1)
        # outstanding (queued/admitted-not-yet-prefilled) request chains
        self._pending: Dict[int, List[Node]] = {}
        self._req_ids = itertools.count(1)
        # metrics
        self.accesses = 0
        self.hits = 0
        self.effective_hits = 0
        self.evictions = 0

    # ------------------------------------------------------------ structure
    def _blocks(self, tokens: Sequence[int]) -> List[TokenBlock]:
        bt = self.block_tokens
        return [tuple(tokens[i:i + bt])
                for i in range(0, len(tokens) - len(tokens) % bt, bt)]

    def _walk(self, tokens: Sequence[int], create: bool = False
              ) -> List[Node]:
        """Nodes along the chain for ``tokens`` (existing, or created
        skeleton nodes when ``create``)."""
        chain: List[Node] = []
        node = self.root
        for key in self._blocks(tokens):
            child = node.children.get(key)
            if child is None:
                if not create:
                    break
                child = Node(key=key, parent=node, uid=next(self._uids))
                node.children[key] = child
            chain.append(child)
            node = child
        return chain

    # ------------------------------------------------------------- requests
    def register_request(self, tokens: Sequence[int]) -> int:
        """Announce a request (queued). Its chain contributes reference
        counts until ``complete_request``. Returns a request id."""
        rid = next(self._req_ids)
        self._pending[rid] = self._walk(tokens, create=True)
        return rid

    def complete_request(self, rid: int) -> None:
        self._pending.pop(rid, None)

    # ---------------------------------------------------------------- reads
    def lookup(self, tokens: Sequence[int]) -> List[Node]:
        """Longest fully-resident chain from the root (the usable prefix).
        Records per-block hit/effective-hit metrics along the way."""
        chain = self._walk(tokens)
        usable: List[Node] = []
        broken = False
        t = next(self._clock)
        for node in chain:
            self.accesses += 1
            if node.resident:
                self.hits += 1
                if not broken:
                    self.effective_hits += 1
                    usable.append(node)
                node.last_touch = t
            if not node.resident:
                broken = True
        return usable

    # --------------------------------------------------------------- writes
    def insert(self, tokens: Sequence[int], payloads: List[Any],
               nbytes_per_block: int) -> None:
        """Store KV payloads for the chain of ``tokens`` (post-prefill)."""
        chain = self._walk(tokens, create=True)
        t = next(self._clock)
        for node, payload in zip(chain, payloads):
            if node.resident:
                continue
            self._make_room(nbytes_per_block, exclude=set(
                n.uid for n in chain))
            node.payload = payload
            node.nbytes = nbytes_per_block
            node.resident = True
            node.last_touch = t
            self.used += nbytes_per_block

    # -------------------------------------------------------------- counts
    def _ref_counts(self) -> Tuple[Dict[int, int], Dict[int, int]]:
        """(plain reference count, effective reference count) per node uid,
        over the pending request chains."""
        rc: Dict[int, int] = {}
        erc: Dict[int, int] = {}
        for chain in self._pending.values():
            broken = False
            for node in chain:
                rc[node.uid] = rc.get(node.uid, 0) + 1
                if not node.resident:
                    broken = True
                if not broken:
                    # every block up to here has all ancestors resident
                    erc[node.uid] = erc.get(node.uid, 0) + 1
        return rc, erc

    def _resident_nodes(self) -> List[Node]:
        out: List[Node] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root and n.resident:
                out.append(n)
        return out

    def _make_room(self, needed: int, exclude: set) -> None:
        while self.used + needed > self.capacity:
            victims = [n for n in self._resident_nodes()
                       if n.uid not in exclude]
            if not victims:
                return
            rc, erc = self._ref_counts()
            if self.policy == "lru":
                key = lambda n: (n.last_touch, -n.depth())
            elif self.policy == "lrc":
                key = lambda n: (rc.get(n.uid, 0), n.last_touch)
            else:  # lerc: fewest effective refs; deepest first on ties
                key = lambda n: (erc.get(n.uid, 0), rc.get(n.uid, 0),
                                 -n.depth(), n.last_touch)
            victim = min(victims, key=key)
            self._evict(victim)

    def _evict(self, node: Node) -> None:
        node.resident = False
        node.payload = None
        self.used -= node.nbytes
        node.nbytes = 0
        self.evictions += 1
        # a resident chain through this node is now broken for descendants;
        # ERC of descendants drops automatically via _ref_counts (the
        # "complete -> incomplete" flip of the paper's protocol)

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, float]:
        return {
            "accesses": self.accesses,
            "hit_ratio": self.hits / self.accesses if self.accesses else 0.0,
            "effective_hit_ratio": (self.effective_hits / self.accesses
                                    if self.accesses else 0.0),
            "evictions": self.evictions,
            "used_bytes": self.used,
        }

"""Disk-backed KV block pool — tier 2 of the serving data plane.

The cheapest rung of the cost hierarchy: one ``np.memmap`` row file per KV
cache leaf, laid out exactly like ``HostBlockPool``'s buffers
``(*lead, num_blocks, block_tokens, KV, D)``, so host↔disk demotion is a
row copy (plus an optional numpy transcode to a narrower dtype) and the
tiered store's payload stays a single int in every tier. Scale arrays are
tiny (one f32 per row per layer sub-block) and stay in RAM — only the bulk
KV bytes live on disk.

Restoring from this tier costs a page-in + host→device transfer, which the
LERC store prices against prefill recompute: a complete chain here is
still cheaper to promote than to regenerate, an incomplete one is pure
waste — the paper's all-or-nothing property applied to the storage ladder.

With ``directory=None`` the files live in a ``TemporaryDirectory`` owned
by the pool (vanishing with the process); pass ``--disk-dir`` to place
them on a chosen filesystem. The pool never grows; the tiered store's
third eviction index frees rows before the byte budget is exceeded.

``close()`` (or the context manager) tears the row files down
deterministically — memmaps closed, files unlinked, the owned temp
directory removed — instead of leaning on ``TemporaryDirectory``'s
finalizer order at interpreter exit, which is undefined relative to the
memmaps' own finalizers and leaks the files entirely when the operator
supplied ``--disk-dir``.

The pool is also the injection point for disk-tier I/O faults: with a
``repro.faults.FaultInjector`` attached (``self.faults``), ``read_rows``
and ``write_rows`` raise ``OSError`` with the plan's configured
probability — exactly the failure surface a real spindle/NVMe presents —
and ``TieredKVStore`` handles quarantine + degraded fallback above.
"""
from __future__ import annotations

import os
import tempfile
from typing import Optional

import numpy as np

from ..quant import QuantSpec
from .host_pool import HostBlockPool


class DiskBlockPool(HostBlockPool):
    """``HostBlockPool`` whose row buffers are file-backed memmaps.

    Same alloc/free/read_rows/write_rows surface (quantized mode
    included); only ``_alloc_buffer`` differs.
    """

    def __init__(self, cache_template, block_tokens: int, num_blocks: int,
                 quant: Optional[QuantSpec] = None,
                 directory: Optional[str] = None) -> None:
        if directory is None:
            self._tmpdir = tempfile.TemporaryDirectory(
                prefix="repro-kv-disk-")
            directory = self._tmpdir.name
        else:
            os.makedirs(directory, exist_ok=True)
            self._tmpdir = None
        self.directory = directory
        self._n_files = 0
        self._memmaps: list = []
        self._paths: list = []
        self.closed = False
        # repro.faults.FaultInjector (None = healthy disk); attached by
        # TieredKVStore so one seeded generator serves the whole run
        self.faults = None
        super().__init__(cache_template, block_tokens, num_blocks,
                         quant=quant)

    def _alloc_buffer(self, shape, dtype) -> np.ndarray:
        path = os.path.join(self.directory, f"leaf{self._n_files}.kv")
        self._n_files += 1
        if any(d == 0 for d in shape):      # zero-row pool: no file
            return np.zeros(shape, dtype)
        buf = np.memmap(path, dtype=dtype, mode="w+", shape=shape)
        self._memmaps.append(buf)
        self._paths.append(path)
        return buf

    # ------------------------------------------------------------ transfers
    def read_rows(self, idxs):
        if self.faults is not None and self.faults.disk_read_fails():
            raise OSError("injected disk read error")
        return super().read_rows(idxs)

    def write_rows(self, idxs, host_blocks, scales=None) -> None:
        if self.faults is not None and self.faults.disk_write_fails():
            raise OSError("injected disk write error")
        super().write_rows(idxs, host_blocks, scales=scales)

    # ------------------------------------------------------------- lifetime
    def close(self) -> None:
        """Deterministic teardown: close every row-file memmap, unlink the
        files, and remove the owned temp directory. Idempotent; reads or
        writes after close fail (the mmaps are gone), which is the point —
        a closed pool must not silently resurrect its files."""
        if self.closed:
            return
        self.closed = True
        for buf in self._memmaps:
            mm = getattr(buf, "_mmap", None)
            if mm is not None:
                mm.close()
        self._memmaps.clear()
        for path in self._paths:
            try:
                os.unlink(path)
            except OSError:
                pass
        self._paths.clear()
        if self._tmpdir is not None:
            self._tmpdir.cleanup()
            self._tmpdir = None

    def __enter__(self) -> "DiskBlockPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Brute-force reference PrefixStore — the retained oracle.

This keeps the seed implementation's *algorithm*: on **every** eviction it
re-walks the whole radix tree for resident nodes and re-derives reference
/ effective-reference counts from **all** pending request chains, then
min-scans for the victim — O(requests × depth + resident) per victim. The
counter *semantics* are the unified chain→peer-group adapter's (depth-
weighted, see below), not the seed's chain-count form, so that the oracle
and the incremental ``PrefixStore`` rank identically by construction.
``tests/test_prefix_oracle.py`` proves both make *identical* eviction
decisions, and ``benchmarks/eviction_scaling.py`` measures the asymptotic
gap between recompute-per-victim and the incremental index.

The counters use the chain→peer-group adapter semantics (one peer group
per pending-chain prefix), computed from scratch:

* ``rc[b]``  = Σ over pending chains containing b at position j of
  (chain length − j)   — one reference per prefix at or below b;
* ``erc[b]`` = the same sum restricted to prefixes that are fully
  resident.

Clock discipline mirrors ``core.policies.Policy`` exactly (one tick per
per-block insert/access, in chain order), so tiebreaks are identical.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, List, Sequence, Tuple, Union

from ..core import CacheMetrics
from .prefix_store import Node, TokenBlock, blocking_cause


class ReferencePrefixStore:
    """Same external behavior as ``PrefixStore`` (for lru/lrc/lerc), via
    full recomputation per victim instead of the incremental index."""

    def __init__(self, capacity_bytes: int, policy: str = "lerc",
                 block_tokens: int = 16) -> None:
        assert policy in ("lru", "lrc", "lerc"), \
            "the brute-force oracle covers the seed's three policies"
        self.capacity = capacity_bytes
        self.policy_name = policy
        self.block_tokens = block_tokens
        self.root = Node(key=(), parent=None, resident=True)
        self.used = 0
        self._uids = itertools.count(1)
        self._req_ids = itertools.count(1)
        self._clock = 0
        self._last_access: Dict[str, int] = {}
        self._pending: Dict[int, List[Node]] = {}
        self.metrics_obj = CacheMetrics()
        self.eviction_log: List[str] = []

    # ------------------------------------------------------------ structure
    def _blocks(self, tokens: Sequence[int]) -> List[TokenBlock]:
        bt = self.block_tokens
        return [tuple(tokens[i:i + bt])
                for i in range(0, len(tokens) - len(tokens) % bt, bt)]

    def _walk(self, tokens: Sequence[int], create: bool = False
              ) -> List[Node]:
        chain: List[Node] = []
        node = self.root
        for key in self._blocks(tokens):
            child = node.children.get(key)
            if child is None:
                if not create:
                    break
                child = Node(key=key, parent=node, uid=next(self._uids))
                node.children[key] = child
            chain.append(child)
            node = child
        return chain

    # ------------------------------------------------------------- requests
    def register_request(self, tokens: Sequence[int]) -> int:
        rid = next(self._req_ids)
        self._pending[rid] = self._walk(tokens, create=True)
        return rid

    def complete_request(self, rid: int) -> None:
        chain = self._pending.pop(rid, None)
        if chain:
            self._prune_chain(chain)

    def _prune_chain(self, chain: List[Node]) -> None:
        """Skeleton GC, brute-force form: a node is referenced iff it
        appears in ANY pending chain (rc > 0 in the incremental store ⟺
        membership here, since every position at or below contributes).
        Must prune exactly the nodes ``PrefixStore`` prunes so that uid
        assignment — and hence eviction logs — stay comparable."""
        referenced = {n.block_id for c in self._pending.values() for n in c}
        for node in reversed(chain):
            if (node.resident or node.children
                    or node.block_id in referenced):
                break
            node.parent.children.pop(node.key, None)
            self._last_access.pop(node.block_id, None)
            node.parent = None

    # ---------------------------------------------------------------- reads
    def lookup(self, tokens: Sequence[int]) -> List[Node]:
        chain = self._walk(tokens)
        usable: List[Node] = []
        touched: List[Node] = []
        broken = False
        cause = None          # first gap's whereabouts, as in PrefixStore
        for node in chain:
            hit = node.resident
            if not hit:
                broken = True
                if cause is None:
                    cause = blocking_cause(node)
            self.metrics_obj.record_access(hit=hit,
                                           effective=hit and not broken,
                                           cause=cause)
            if hit:
                if not broken:
                    usable.append(node)
                touched.append(node)
        for node in reversed(touched):            # leaf first, root last
            self._clock += 1
            self._last_access[node.block_id] = self._clock
        return usable

    # --------------------------------------------------------------- writes
    def insert(self, tokens: Sequence[int],
               payloads: Union[List[Any], Callable[[int, Node], Any]],
               nbytes_per_block: int) -> None:
        chain = self._walk(tokens, create=True)
        exclude = {n.block_id for n in chain}
        fresh: List[Node] = []
        if not callable(payloads):
            chain = chain[:len(payloads)]
        for i, node in enumerate(chain):
            if node.resident:
                continue
            self._make_room(nbytes_per_block, exclude=exclude)
            node.payload = (payloads(i, node) if callable(payloads)
                            else payloads[i])
            node.nbytes = nbytes_per_block
            node.resident = True
            node.ever_resident = True
            self.used += nbytes_per_block
            fresh.append(node)
        for node in reversed(fresh):              # leaf first, root last
            self._clock += 1
            self._last_access[node.block_id] = self._clock

    # -------------------------------------------------------------- counts
    def _ref_counts(self) -> Tuple[Dict[str, int], Dict[str, int]]:
        """From-scratch (rc, erc) over every pending chain — the seed's
        per-eviction recomputation."""
        rc: Dict[str, int] = {}
        erc: Dict[str, int] = {}
        for chain in self._pending.values():
            k = len(chain)
            # last position whose whole prefix is resident (-1 if none)
            last_complete = -1
            for i, node in enumerate(chain):
                if not node.resident:
                    break
                last_complete = i
            for j, node in enumerate(chain):
                b = node.block_id
                rc[b] = rc.get(b, 0) + (k - j)
                if j <= last_complete:
                    erc[b] = erc.get(b, 0) + (last_complete - j + 1)
        return rc, erc

    def _resident_nodes(self) -> List[Node]:
        out: List[Node] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root and n.resident:
                out.append(n)
        return out

    def _make_room(self, needed: int, exclude: set) -> None:
        while self.used + needed > self.capacity:
            victims = [n for n in self._resident_nodes()
                       if n.block_id not in exclude]
            if not victims:
                return
            rc, erc = self._ref_counts()
            la = self._last_access
            if self.policy_name == "lru":
                key = lambda n: la.get(n.block_id, 0)
            elif self.policy_name == "lrc":
                key = lambda n: (rc.get(n.block_id, 0),
                                 la.get(n.block_id, 0))
            else:  # lerc
                key = lambda n: (erc.get(n.block_id, 0),
                                 rc.get(n.block_id, 0),
                                 la.get(n.block_id, 0))
            self._evict(min(victims, key=key))

    def _evict(self, node: Node) -> None:
        node.resident = False
        node.payload = None
        self.used -= node.nbytes
        node.nbytes = 0
        self.metrics_obj.evictions += 1
        self.eviction_log.append(node.block_id)

    # -------------------------------------------------------------- metrics
    @property
    def evictions(self) -> int:
        return self.metrics_obj.evictions

    def metrics(self) -> Dict[str, float]:
        self.metrics_obj.check_attribution()
        return {**self.metrics_obj.as_dict(), "used_bytes": self.used}

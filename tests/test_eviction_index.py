"""EvictionIndex: lazy-heap victim selection must equal the full sort."""
import random

import pytest

from repro.core import (BlockMeta, CacheManager, DagState, EvictionIndex,
                        JobDAG, TaskSpec, make_policy)


def chain_dag(n_blocks=12, n_tasks=6, seed=0):
    rng = random.Random(seed)
    dag = JobDAG()
    for i in range(n_blocks):
        dag.add_source("s", i, size=1)
    for t in range(n_tasks):
        k = rng.randint(1, 3)
        inputs = tuple(f"s[{i}]" for i in sorted(
            rng.sample(range(n_blocks), k)))
        dag.add_block(BlockMeta(f"o{t}", 1, "o", t))
        dag.add_task(TaskSpec(f"t{t}", inputs, f"o{t}", job="j"))
    return dag


@pytest.mark.parametrize("policy_name",
                         ["lru", "mru", "fifo", "lfu", "lrc", "lerc",
                          "sticky"])
def test_index_pops_equal_sorted_order(policy_name):
    """Draining the index must reproduce the policy's full sorted ranking
    at every point of a random event history."""
    rng = random.Random(1)
    dag = chain_dag()
    state = DagState(dag)
    policy = make_policy(policy_name)
    index = EvictionIndex(policy, state)
    members = set()

    def check():
        # index is consumed by popping: compare against a sorted oracle
        expect = sorted(members, key=lambda b: policy.eviction_key(b, state))
        got = []
        while True:
            b = index.pop_min()
            if b is None:
                break
            got.append(b)
        assert got == expect
        for b in got:                      # restore
            index.add(b)

    blocks = sorted(dag.blocks)
    for step in range(200):
        b = rng.choice(blocks)
        op = rng.random()
        if op < 0.3 and b not in members:
            members.add(b)
            state.on_materialized(b, into_cache=True)
            policy.on_insert(b)
            index.add(b)
        elif op < 0.5 and b in members:
            members.discard(b)
            index.discard(b)
            policy.on_remove(b)
            state.on_evicted(b)
        elif op < 0.8 and b in members:
            policy.on_access(b)
        elif op < 0.9:
            t = rng.choice(sorted(dag.tasks))
            state.on_task_done(t)
        else:
            state.rebuild()                # notifies -> index.rebuild
        if step % 20 == 0:
            check()
    check()


def test_index_excluded_blocks_stay_tracked():
    dag = chain_dag()
    state = DagState(dag)
    policy = make_policy("lru")
    index = EvictionIndex(policy, state)
    for i in range(4):
        b = f"s[{i}]"
        policy.on_insert(b)
        index.add(b)
    assert index.pop_min(exclude={"s[0]", "s[1]", "s[2]", "s[3]"}) is None
    assert len(index) == 4                 # all still tracked
    assert index.pop_min(exclude={"s[0]"}) == "s[1]"
    assert index.pop_min() == "s[0]"


def test_index_compaction_preserves_order():
    dag = chain_dag()
    state = DagState(dag)
    policy = make_policy("lru")
    index = EvictionIndex(policy, state)
    for i in range(6):
        b = f"s[{i}]"
        policy.on_insert(b)
        index.add(b)
    # churn far past the compaction threshold
    for _ in range(200):
        for i in range(6):
            policy.on_access(f"s[{i}]")    # invalidates via _touch
    assert len(index._heap) <= 2 * len(index) + 70
    drained = [index.pop_min() for _ in range(6)]
    assert drained == [f"s[{i}]" for i in range(6)]


def test_cache_manager_uses_index_and_matches_sorted_fallback():
    """End-to-end: CacheManager victims under the index equal the seed's
    sorted choose_victims on an identical twin."""
    rng = random.Random(2)
    dag = chain_dag(seed=3)

    def run(use_index):
        state = DagState(dag)
        policy = make_policy("lerc")
        mgr = CacheManager(capacity=4, policy=policy, state=state)
        if not use_index:
            # route eviction through the seed's sorted full scan instead
            mgr._evict_for = lambda needed: _sorted_evict(mgr, needed)
        victims_log = []
        orig_evict = mgr.evict
        mgr.evict = lambda b: (victims_log.append(b), orig_evict(b))
        r = random.Random(7)
        for _ in range(60):
            b = r.choice(sorted(dag.blocks))
            if b not in mgr.mem and dag.blocks[b].size <= 4:
                mgr.insert(b, dag.blocks[b].size)
        return victims_log

    def _sorted_evict(mgr, needed):
        if needed <= mgr.mem.free:
            return []
        victims = mgr.policy.choose_victims(
            list(mgr.mem.blocks), needed - mgr.mem.free, mgr.mem.blocks,
            mgr.state, pinned=mgr.pinned)
        for v in victims:
            mgr.evict(v)
        return victims

    assert run(True) == run(False)

"""Sharding-rule unit tests (no devices needed: pure PartitionSpec logic
over a stub mesh)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamSpec
from repro.sharding import MeshContext


class _StubMesh:
    """Quacks like jax.sharding.Mesh for axis-size queries."""

    def __init__(self, shape):
        self.shape = shape
        self.size = 1
        for v in shape.values():
            self.size *= v


def ctx(multi_pod=False, **kw):
    shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
             else {"data": 16, "model": 16})
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return MeshContext(mesh=_StubMesh(shape), data_axes=data_axes, **kw)


def test_tp_and_fsdp_assignment():
    c = ctx()
    wq = ParamSpec((8192, 64, 128), ("embed", "heads", "head_dim"))
    assert c.param_pspec(wq) == P("data", "model")
    wi = ParamSpec((8192, 2, 49152), ("embed", None, "ff"))
    assert c.param_pspec(wi) == P("data", None, "model")
    tok = ParamSpec((152064, 8192), ("vocab", "embed"))
    assert c.param_pspec(tok) == P("model", "data")


def test_gathered_layout_drops_fsdp():
    c = ctx()
    wi = ParamSpec((8192, 2, 49152), ("embed", None, "ff"))
    assert c.param_pspec(wi, fsdp=False) == P(None, None, "model")


def test_divisibility_fallback():
    c = ctx()
    # kv_heads = 8 does not divide model=16 -> replicated
    wk = ParamSpec((8192, 8, 128), ("embed", "kv_heads", "head_dim"))
    assert c.param_pspec(wk) == P("data")
    # odd embed dim -> no fsdp either
    odd = ParamSpec((4097, 8, 128), ("embed", "kv_heads", "head_dim"))
    assert c.param_pspec(odd) == P()


def test_axis_used_once_per_tensor():
    c = ctx()
    # experts and ff both want "model": experts (first) wins
    wi = ParamSpec((64, 2048, 2, 1408), ("experts", "embed", None, "ff"))
    spec = c.param_pspec(wi)
    assert spec == P("model", "data")
    flat = [a for a in spec if a is not None]
    assert len(flat) == len(set(flat))


def test_multi_pod_fsdp_spans_pod_and_data():
    c = ctx(multi_pod=True)
    wi = ParamSpec((8192, 2, 49152), ("embed", None, "ff"))
    assert c.param_pspec(wi) == P(("pod", "data"), None, "model")
    assert c.dp_size == 32


def test_stacked_layer_axis_stays_replicated():
    c = ctx()
    stacked = ParamSpec((80, 8192, 2, 49152),
                        ("layer", "embed", None, "ff"))
    assert c.param_pspec(stacked) == P(None, "data", None, "model")


def test_batch_pspec_sp():
    c = ctx()
    assert c.batch_pspec((256, 4096)) == P("data", "model")
    # batch of 1: nothing shardable on dim 0
    assert c.batch_pspec((1, 4096)) == P(None, "model")
    c2 = ctx()
    c2.seq_shard = False
    assert c2.batch_pspec((256, 4096)) == P("data", None)


def test_cache_pspec_kv_and_fallbacks():
    c = ctx()
    # stacked KV: (layer, B, S, KV, D) -> B over data, KV over model
    p = c.cache_pspec(("stack", "0_G", "k"), (28, 128, 32768, 16, 128))
    assert p == P(None, "data", None, "model")
    # MQA (KV=1) + batch 1 (long context): S spread over data AND model
    p = c.cache_pspec(("stack", "1_G", "k"), (23, 1, 524288, 16, 128))
    assert p == P(None, None, "data", "model")
    # whisper: KV=8 not divisible -> S over model
    p = c.cache_pspec(("k",), (6, 128, 32768, 8, 64))
    assert p == P(None, "data", "model")


def test_cache_pspec_recurrent_states():
    c = ctx()
    p = c.cache_pspec(("stack", "0_R", "h"), (12, 128, 4096))
    assert p == P(None, "data", "model")
    p = c.cache_pspec(("stack", "0_W", "S"), (32, 128, 16, 160, 160))
    assert p == P(None, "data", "model")

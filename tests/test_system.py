"""End-to-end system tests: the paper's claims on the real substrates."""
import numpy as np
import pytest

from repro.sim import ClusterSim, HardwareModel, multi_tenant_zip


def _run(policy, cache_gb=5.3, n_jobs=4, n_blocks=40):
    hw = HardwareModel(cache_bytes=int(cache_gb * 2 ** 30) // 20,
                       disk_bw=25e6)
    sim = ClusterSim(20, hw, policy=policy)
    for dag, _ in multi_tenant_zip(n_jobs=n_jobs, n_blocks=n_blocks,
                                   n_workers=20):
        sim.submit(dag)
    sim.run(stages={0})
    return sim.run(stages={1})


def test_paper_headline_ordering():
    """Makespan: LERC <= LRC <= LRU on the paper's workload (§IV)."""
    res = {p: _run(p, cache_gb=2.0) for p in ("lru", "lrc", "lerc")}
    assert res["lerc"].makespan <= res["lrc"].makespan <= res["lru"].makespan
    assert res["lerc"].makespan < res["lru"].makespan  # strict win


def test_effective_ratio_tracks_runtime_better():
    """The paper's metric claim: effective hit ratio orders policies the
    same way runtime does, while plain hit ratio can be misleading (LRC
    matches LERC on hit ratio yet is slower)."""
    res = {p: _run(p, cache_gb=2.0) for p in ("lru", "lrc", "lerc")}
    ehr = {p: r.metrics.effective_hit_ratio for p, r in res.items()}
    mk = {p: r.makespan for p, r in res.items()}
    # higher effective ratio -> lower makespan, strictly ordered
    order_by_ehr = sorted(ehr, key=lambda p: -ehr[p])
    order_by_mk = sorted(mk, key=lambda p: mk[p])
    assert order_by_ehr[0] == order_by_mk[0] == "lerc"
    # LRC achieves LERC-level plain hit ratio but lower effective ratio
    assert res["lrc"].metrics.hit_ratio >= 0.9 * res["lerc"].metrics.hit_ratio
    assert ehr["lerc"] > ehr["lrc"]


def test_sim_message_accounting():
    res = _run("lerc", cache_gb=2.0)
    # protocol: every eviction broadcast corresponds to one report
    assert res.messages.eviction_broadcasts == res.messages.eviction_reports
    # and broadcasts never exceed evictions
    assert res.messages.eviction_broadcasts <= res.metrics.evictions
    # bytes accounting rides every message
    assert res.messages.payload_bytes > res.messages.lerc_bytes > 0


def test_message_stats_are_real_bus_traffic():
    """Message counts come exclusively from MessageBus traffic: the stats
    object IS the bus's, and a DAG-oblivious policy — which deploys no
    coordination protocol — produces zero LERC-channel traffic while the
    legacy status channel still flows."""
    hw = HardwareModel(cache_bytes=int(2.0 * 2 ** 30) // 20, disk_bw=25e6)
    sim = ClusterSim(20, hw, policy="lru")
    assert sim.messages is sim.bus.stats
    for dag, _ in multi_tenant_zip(n_jobs=2, n_blocks=20, n_workers=20):
        sim.submit(dag)
    sim.run(stages={0})
    res = sim.run(stages={1})
    assert res.messages.peer_profile_broadcasts == 0
    assert res.messages.eviction_reports == 0
    assert res.messages.eviction_broadcasts == 0
    assert res.messages.lerc_bytes == 0
    # ...but the legacy block-status channel is real traffic
    assert res.messages.point_to_point > 0
    assert res.messages.payload_bytes > 0


def test_sim_replicas_bit_identical():
    """Every worker's bus-fed DagState replica agrees with the driver's
    authoritative state (run() verifies internally; assert it directly
    too, after a run with heavy eviction traffic)."""
    res = _run("lerc", cache_gb=1.0)
    assert res.metrics.evictions > 0
    hw = HardwareModel(cache_bytes=int(1.0 * 2 ** 30) // 20, disk_bw=25e6)
    sim = ClusterSim(20, hw, policy="lerc")
    for dag, _ in multi_tenant_zip(n_jobs=3, n_blocks=30, n_workers=20):
        sim.submit(dag)
    sim.run(stages={0})
    sim.run(stages={1})
    sim.verify_replicas()
    ms = sim.master.state
    for tr in sim.trackers:
        assert tr.state.cached == ms.cached
        for b in sim.master.dag.blocks:
            assert tr.state.eff_ref_count.get(b, 0) == \
                ms.eff_ref_count.get(b, 0)


def test_belady_optimizes_the_wrong_metric():
    """The paper's thesis, sharpened: Belady/MIN is hit-ratio-OPTIMAL yet
    can LOSE to LERC on makespan, because hit ratio is the wrong objective
    under the all-or-nothing property. The clairvoyant bound must win the
    metric it optimizes; LERC must match or beat it on runtime."""
    from repro.sim import zip_access_trace
    n_jobs, n_blocks = 3, 30
    trace = zip_access_trace(n_jobs, n_blocks)
    hw = HardwareModel(cache_bytes=int(1.5 * 2 ** 30) // 20, disk_bw=25e6)

    def run_with(policy):
        sim = ClusterSim(20, hw, policy=policy)
        for dag, _ in multi_tenant_zip(n_jobs=n_jobs, n_blocks=n_blocks,
                                       n_workers=20):
            sim.submit(dag)
        sim.run(stages={0})
        return sim.run(stages={1}, belady_trace=trace)

    lerc = run_with("lerc")
    belady = run_with("belady")
    # the clairvoyant policy wins (or ties) the metric it optimizes...
    assert belady.metrics.hit_ratio >= lerc.metrics.hit_ratio * 0.999
    # ...but LERC matches or beats it on what actually matters
    assert lerc.makespan <= belady.makespan * 1.05


def test_msg_latency_charges_bus_delay():
    """HardwareModel.msg_latency delays the driver learning that a task
    became runnable by one status-report hop (charged when the LAST
    missing producer reports — a join pays one hop, not one per edge), so
    each link of a linear chain adds exactly one hop to the makespan; the
    default (0) is the seed's instantaneous bus."""
    import pytest as _pytest

    from repro.core import BlockMeta, JobDAG, TaskSpec

    assert HardwareModel().msg_latency == 0.0

    def chain_job(n=5, size=10 * 2 ** 20):
        dag = JobDAG()
        prev = dag.add_source("src", 0, size=size).id
        for i in range(n):
            dag.add_block(BlockMeta(id=f"b{i}", size=size, dataset="d",
                                    index=i))
            dag.add_task(TaskSpec(id=f"t{i}", inputs=(prev,),
                                  output=f"b{i}", job="j"))
            prev = f"b{i}"
        return dag

    def run(latency):
        sim = ClusterSim(2, HardwareModel(msg_latency=latency),
                         policy="lerc")
        sim.submit(chain_job())
        return sim.run()

    base = run(0.0)
    delayed = run(0.5)
    # 5 tasks, 4 producer->consumer edges, one hop each
    assert delayed.makespan == _pytest.approx(base.makespan + 4 * 0.5)
    # the delay is pure scheduling latency: caching behavior unchanged
    assert delayed.metrics.hits == base.metrics.hits
    assert delayed.metrics.evictions == base.metrics.evictions

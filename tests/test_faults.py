"""Deterministic fault injection + graceful degradation (robustness PR).

Contracts:

(1) an **empty FaultPlan is bit-identical to no plan at all** — tokens,
eviction logs and the full metrics dict — on every serve configuration
(paged / tiered / sharded / tp=2) and in the simulator; (2) a seeded
shard crash fails over: every admitted request finishes, surviving
requests generate token-identically to the clean run, and the rebuilt
replica reconverges through the anti-entropy resync; (3) a disk tier
failing reads quarantines after ``quarantine_after`` consecutive errors
and the run degrades to eviction + recompute with zero uncaught
exceptions; (4) slow promotions charge the virtual clock exactly, and
promotions stalled past the timeout abandon cleanly (recompute, same
tokens); (5) a sim worker crash recomputes lost blocks through the DAG
lineage with the makespan charged *exactly*; (6) ``on_lost`` /
``on_task_undone`` agree with the from-scratch ``rebuild()`` oracle."""
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import BlockMeta, DagState, JobDAG, TaskSpec
from repro.faults import BusFault, FaultPlan
from repro.models import init_params, model_spec
from repro.models.common import ModelConfig
from repro.serve import (PrefixStore, QueueFull, ServeEngine,
                         ShardedFrontend, TieredKVStore, TracedRequest,
                         latency_stats, play_trace)
from repro.sharding import serve_tp_context
from repro.sim import ClusterSim, HardwareModel, poisson_arrivals

BT = 8          # block_tokens
PROMPT = 40     # uniform prompt length (5 blocks: 4 prefix + 1 suffix)
MAX_NEW = 4
DEADLINE = 60.0


@pytest.fixture(scope="module")
def model():
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    return cfg, params


def _blk(cfg, params):
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    return probe._block_nbytes()


def workload(vocab, n_requests=12, n_families=4, seed=3,
             prefix_tokens=PROMPT - BT):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, prefix_tokens))
                for _ in range(n_families)]
    return [prefixes[i % n_families]
            + list(rng.integers(0, vocab, BT)) for i in range(n_requests)]


def _timed_trace(vocab, n_requests=12, rate=1.5, seed=3):
    reqs = workload(vocab, n_requests)
    times = poisson_arrivals(n_requests, rate=rate, seed=seed)
    return [TracedRequest(t=t, prompt=p, max_new=MAX_NEW,
                          deadline=DEADLINE)
            for t, p in zip(times, reqs)]


def _by_key(requests):
    """Cross-run token comparison key. rids are per-shard counters (they
    collide across shards), so identity is (prompt, arrival)."""
    out = {}
    for r in requests:
        out[(tuple(r.prompt), r.arrival)] = list(r.generated)
    return out


# ---------------------------------------------------------------------------
# (1) empty plan == no plan, bit for bit
# ---------------------------------------------------------------------------

def test_empty_plan_bit_identity_tiered(model):
    """A tiered engine carrying an empty-plan injector is op-for-op the
    healthy engine: tokens, all three eviction logs, full metrics dict."""
    cfg, params = model
    blk = _blk(cfg, params)
    reqs = workload(cfg.vocab)

    def run(injector):
        store = TieredKVStore(6 * blk, "lerc", block_tokens=BT,
                              host_capacity_bytes=3 * blk,
                              disk_capacity_bytes=64 * blk)
        store.faults = injector
        eng = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                          store=store, prefill_chunk=BT)
        rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
        eng.run()
        m = eng.metrics()
        eng.close()
        return [r.generated for r in rs], store, m

    base_toks, base_st, base_m = run(None)
    toks, st, m = run(FaultPlan().injector())
    assert base_st.evictions > 0, "workload produced no pressure"
    assert toks == base_toks
    assert st.eviction_log == base_st.eviction_log
    assert st.host_eviction_log == base_st.host_eviction_log
    assert st.disk_eviction_log == base_st.disk_eviction_log
    assert m == base_m


def test_empty_plan_bit_identity_sharded(model):
    """A 2-shard frontend built over FaultPlan() replays a timed trace —
    through the same ``play_trace`` dispatch a faulted run would take the
    door of — bit-identically to faults=None: tokens, latency stats, the
    full metrics dict (all fault counters present and zero)."""
    cfg, params = model
    blk = _blk(cfg, params)
    trace = _timed_trace(cfg.vocab)

    def run(faults):
        fe = ShardedFrontend(cfg, params, 2, max_slots=2, max_seq=64,
                             capacity_bytes=10 * blk, policy="lerc",
                             block_tokens=BT, prefill_chunk=BT,
                             max_queue=64, faults=faults)
        report = play_trace(fe, trace)
        stats = latency_stats(report)
        fe.verify_replicas()
        m = fe.metrics()
        fe.close()
        return _by_key(report.requests), stats, m

    base = run(None)
    empty = run(FaultPlan())
    assert empty == base
    m = empty[2]
    assert m["shard_crashes"] == 0 and m["failover_retries"] == 0
    assert m["msg_dropped"] == 0 and m["msg_resyncs"] == 0


def test_empty_plan_bit_identity_paged(model):
    """Same identity on the paged data plane (batch loop, 2 shards)."""
    cfg, params = model
    blk = _blk(cfg, params)
    reqs = workload(cfg.vocab)

    def run(faults):
        fe = ShardedFrontend(cfg, params, 2, max_slots=1, max_seq=64,
                             capacity_bytes=10 * blk, policy="lerc",
                             block_tokens=BT, paged=True,
                             record_eviction_log=True, faults=faults)
        rs = [fe.submit(r, max_new=MAX_NEW)[1] for r in reqs]
        fe.run()
        fe.verify_replicas()
        logs = [eng.store.eviction_log for eng in fe.shards]
        m = fe.metrics()
        fe.close()
        return [r.generated for r in rs], logs, m

    assert run(FaultPlan()) == run(None)


TP_CFG = ModelConfig(arch="tp_smoke", family="dense", n_layers=2,
                     d_model=32, n_heads=8, n_kv_heads=4, d_head=8,
                     d_ff=64, vocab=256, act="swiglu", layer_pattern="G")


def test_empty_plan_bit_identity_tp2():
    """Same identity on a tp=2 paged engine over a tiered store (the
    injector rides the store). Needs forced host devices — the CI TP leg
    runs with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    cfg = TP_CFG
    params = init_params(jax.random.key(0), model_spec(cfg),
                        dtype=cfg.dtype)
    blk = _blk(cfg, params)
    reqs = workload(cfg.vocab, n_requests=10, n_families=2, seed=5)

    def run(injector):
        store = TieredKVStore(6 * blk, "lerc", block_tokens=BT,
                              host_capacity_bytes=64 * blk)
        store.faults = injector
        eng = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                          store=store, prefill_chunk=BT, paged=True,
                          kv_shard=serve_tp_context(2))
        rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
        eng.run()
        return ([r.generated for r in rs], store.eviction_log,
                store.host_eviction_log, eng.metrics())

    assert run(FaultPlan().injector()) == run(None)


# ---------------------------------------------------------------------------
# (2) shard-crash failover
# ---------------------------------------------------------------------------

def test_shard_crash_failover(model):
    """Kill shard 0 mid-trace under a lossy status channel: the crash
    fires exactly once, every admitted request still finishes, every
    request generates token-identically to the clean run (failover
    re-prefills, it does not re-sample), and after the anti-entropy
    resync the rebuilt replica passes the bit-identity proof."""
    cfg, params = model
    blk = _blk(cfg, params)
    trace = _timed_trace(cfg.vocab)
    plan = FaultPlan(seed=7, shard_crashes=((5.0, 0),),
                     bus_faults=(BusFault(channel="status", drop_p=0.2),))

    def run(faults):
        fe = ShardedFrontend(cfg, params, 2, max_slots=2, max_seq=64,
                             capacity_bytes=48 * blk, policy="lerc",
                             block_tokens=BT, prefill_chunk=BT,
                             max_queue=64, faults=faults)
        report = play_trace(fe, trace)
        return fe, report

    clean_fe, clean_report = run(None)
    clean_fe.verify_replicas()
    clean_fe.close()

    fe, report = run(plan)
    m = fe.metrics()
    assert m["shard_crashes"] == 1, "scheduled crash did not fire"
    assert fe.faults.counters.get("fault.shard_crash") == 1
    unfinished = [r for r in report.requests
                  if not r.cancelled and r.finished_at is None]
    assert not unfinished, f"failover lost {len(unfinished)} requests"
    # determinism of the surviving work: token identity keyed by
    # (prompt, arrival) — NOT rid, which collides across shards
    assert _by_key(report.requests) == _by_key(clean_report.requests)
    # retries are visible and bounded by the crash's in-flight set
    assert m["failover_retries"] >= 1
    assert m["msg_dropped"] > 0
    fe.resync_replicas()
    fe.verify_replicas()
    assert fe.metrics()["msg_resyncs"] >= 1
    fe.close()


def test_bus_drop_resync_converges(model):
    """A lossy status channel alone (no crash): replicas may diverge
    (counted, not raised), and one anti-entropy round restores the
    bit-identity proof."""
    cfg, params = model
    blk = _blk(cfg, params)
    reqs = workload(cfg.vocab)
    fe = ShardedFrontend(
        cfg, params, 2, max_slots=1, max_seq=64,
        capacity_bytes=10 * blk, policy="lerc", block_tokens=BT,
        faults=FaultPlan(seed=11, bus_faults=(
            BusFault(channel="status", drop_p=0.3),)))
    rs = [fe.submit(r, max_new=MAX_NEW)[1] for r in reqs]
    fe.run()
    assert all(r.done for r in rs)
    assert fe.bus.stats.dropped > 0, "lossy channel dropped nothing"
    fe.resync_replicas()
    fe.verify_replicas()
    assert fe.bus.stats.resyncs >= fe.n_shards
    fe.close()


# ---------------------------------------------------------------------------
# (3) disk quarantine
# ---------------------------------------------------------------------------

def test_disk_quarantine_graceful(model):
    """Every disk read fails: after ``quarantine_after`` consecutive
    errors the tier is fenced (exactly one quarantine), the run completes
    with zero uncaught exceptions, and the store degrades to two-tier
    semantics — no further disk demotions."""
    cfg, params = model
    blk = _blk(cfg, params)
    store = TieredKVStore(8 * blk, "lerc", block_tokens=BT,
                          host_capacity_bytes=3 * blk,
                          disk_capacity_bytes=64 * blk)
    store.faults = FaultPlan(disk_read_error_p=1.0,
                             quarantine_after=2).injector()
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=96,
                      store=store, prefill_chunk=BT)
    rng = np.random.default_rng(5)
    prefixes = [list(rng.integers(0, cfg.vocab, 32)) for _ in range(3)]
    suffix = list(rng.integers(0, cfg.vocab, BT))
    done = 0
    for pfx in prefixes:                     # warm: demote down the rungs
        r = eng.submit(pfx + suffix, max_new=MAX_NEW)
        eng.run()
        done += r.done
    for pfx in prefixes:                     # re-reference: reads fail
        r = eng.submit(list(pfx), max_new=MAX_NEW)
        eng.run()
        done += r.done
    m = eng.metrics()
    eng.close()
    assert done == 2 * len(prefixes), "degraded engine dropped requests"
    assert m["disk_quarantines"] == 1
    assert m["disk_io_errors"] >= 2
    assert store.disk_quarantined and not store.disk_tiered


def test_disk_write_failures_count_but_reads_reset(model):
    """The consecutive-error counter resets ONLY on a successful disk
    read: a disk that still accepts demotion writes but fails every
    promote must quarantine anyway (writes landing doesn't prove the
    bytes come back)."""
    cfg, params = model
    blk = _blk(cfg, params)
    store = TieredKVStore(6 * blk, "lerc", block_tokens=BT,
                          host_capacity_bytes=2 * blk,
                          disk_capacity_bytes=64 * blk)
    store.faults = FaultPlan(disk_read_error_p=1.0,
                             quarantine_after=3).injector()
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=96,
                      store=store, prefill_chunk=BT)
    rng = np.random.default_rng(9)
    prefixes = [list(rng.integers(0, cfg.vocab, 32)) for _ in range(4)]
    # interleave re-references (failed reads) with fresh warms (successful
    # writes) — the writes must NOT rescue the failing tier
    for i in range(2):
        for pfx in prefixes:
            eng.submit(pfx + [i], max_new=MAX_NEW)
            eng.run()
            eng.submit(list(pfx), max_new=MAX_NEW)
            eng.run()
    m = eng.metrics()
    eng.close()
    assert m["disk_quarantines"] == 1
    assert m["disk_demotions"] > 0, "no successful writes interleaved"


# ---------------------------------------------------------------------------
# (4) promotion stalls + timeouts
# ---------------------------------------------------------------------------

def _promotion_workload(cfg, params, blk, plan):
    store = TieredKVStore(6 * blk, "lerc", block_tokens=BT,
                          host_capacity_bytes=64 * blk)
    if plan is not None:
        store.faults = plan.injector()
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                      store=store, prefill_chunk=BT)
    reqs = workload(cfg.vocab)
    rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
    eng.run()
    return eng, store, [r.generated for r in rs]


def test_promotion_stall_charged_to_clock_exactly(model):
    """Every promotion stalls 2.0 virtual-seconds: tokens unchanged, and
    the engine clock lands exactly ``stalls * 2.0`` past the clean run's
    (the stall drains into ``now`` once per step, after compute)."""
    cfg, params = model
    blk = _blk(cfg, params)
    clean_eng, clean_st, clean_toks = _promotion_workload(
        cfg, params, blk, None)
    assert clean_st.metrics_obj.promotions > 0, "no promotion exercised"
    eng, st, toks = _promotion_workload(
        cfg, params, blk,
        FaultPlan(promotion_stall_p=1.0, promotion_stall=2.0))
    stalls = st.metrics_obj.promotion_stalls
    assert stalls > 0
    assert toks == clean_toks
    assert st.metrics_obj.promotions == clean_st.metrics_obj.promotions
    assert eng.now == pytest.approx(clean_eng.now + 2.0 * stalls)


def test_promotion_timeout_abandons_and_recomputes(model):
    """Stall (2.0) past the timeout (1.0): every promotion is abandoned
    *before* any index/payload mutation — the chain recomputes through
    prefill, tokens unchanged, and no stall is charged."""
    cfg, params = model
    blk = _blk(cfg, params)
    _, clean_st, clean_toks = _promotion_workload(cfg, params, blk, None)
    eng, st, toks = _promotion_workload(
        cfg, params, blk,
        FaultPlan(promotion_stall_p=1.0, promotion_stall=2.0,
                  promotion_timeout=1.0))
    m = st.metrics_obj
    assert m.promotion_timeouts > 0
    assert m.promotion_stalls == 0
    assert toks == clean_toks
    assert m.promotions < clean_st.metrics_obj.promotions
    assert eng.prefill_tokens > 0


# ---------------------------------------------------------------------------
# satellite: cancellation racing an in-flight promotion
# ---------------------------------------------------------------------------

def test_cancel_racing_promotion(model):
    """Cancel a request whose chain was just promoted from the host tier,
    mid-prefill: rows return to the pool, the store's pending references
    retire, and the engine keeps serving — token-identically for the
    survivors. Repeats with the promotion *abandoned* by timeout (the
    cancel then races a recompute instead)."""
    cfg, params = model
    blk = _blk(cfg, params)
    for plan in (None,
                 FaultPlan(promotion_stall_p=1.0, promotion_stall=2.0,
                           promotion_timeout=1.0)):
        store = TieredKVStore(6 * blk, "lerc", block_tokens=BT,
                              host_capacity_bytes=64 * blk)
        if plan is not None:
            store.faults = plan.injector()
        eng = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                          store=store, prefill_chunk=BT)
        rng = np.random.default_rng(2)
        fam = list(rng.integers(0, cfg.vocab, PROMPT - BT))
        warm = eng.submit(fam + list(rng.integers(0, cfg.vocab, BT)),
                          max_new=MAX_NEW)
        eng.run()                      # fam's chain demotes under pressure
        for _ in range(8):             # pressure so fam leaves the device
            eng.submit(list(rng.integers(0, cfg.vocab, PROMPT)),
                       max_new=MAX_NEW)
            eng.run()
        base = store.metrics_obj.promotions + store.metrics_obj.promotion_timeouts
        victim = eng.submit(fam + list(rng.integers(0, cfg.vocab, BT)),
                            max_new=MAX_NEW)
        eng.step()                     # promotion (or its timeout) fires
        assert (store.metrics_obj.promotions
                + store.metrics_obj.promotion_timeouts) > base
        assert not victim.done
        assert eng.cancel(victim)
        assert victim.cancelled and not eng.cancel(victim)

        other = eng.submit(fam + list(rng.integers(0, cfg.vocab, BT)),
                           max_new=MAX_NEW)
        eng.run()
        assert other.done and len(other.generated) == MAX_NEW
        # no leaked rows: pool usage bounded by store-resident blocks
        resident = sum(1 for n in store._nodes.values() if n.resident)
        assert eng.pool.blocks_in_use <= resident + 1       # junk row
        assert eng.metrics()["cancellations"] == 1


# ---------------------------------------------------------------------------
# satellite: QueueFull carries depth + retry-after; retries are counted
# ---------------------------------------------------------------------------

def test_queuefull_enriched_and_retries_counted(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                      store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                      max_queue=1)
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab, PROMPT)) for _ in range(3)]
    eng.submit(prompts[0], max_new=MAX_NEW)     # queue now at max_queue=1
    with pytest.raises(QueueFull) as exc:
        eng.submit(prompts[1], max_new=MAX_NEW)
    assert exc.value.depth == 1
    assert exc.value.retry_after is not None and exc.value.retry_after > 0

    from benchmarks.trace_report import latency_from_trace
    from repro.obs import TraceRecorder
    rec = TraceRecorder()
    eng2 = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                       store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                       max_queue=1)
    eng2.attach_trace(rec)
    trace = [TracedRequest(t=0.0, prompt=p, max_new=MAX_NEW)
             for p in prompts]
    report = play_trace(eng2, trace, retry_rejected=3)
    stats = latency_stats(report)
    assert report.retried > 0
    assert stats["n_retried"] == report.retried
    assert stats["n_rejected"] == 0, "retries should have absorbed the burst"
    assert len(report.requests) == len(trace)
    # trace-side reconstruction splits retried bounces (sched.retry
    # instants) from final rejections — parity with the live stats
    assert latency_from_trace(rec.export()["traceEvents"]) == stats


# ---------------------------------------------------------------------------
# satellite: launch flag validation fails fast with actionable errors
# ---------------------------------------------------------------------------

BAD_FLAG_COMBOS = [
    ["--disk-cache-mb", "16"],                  # disk rung without host tier
    ["--disk-dir", "/tmp/nope"],                # dir without a disk tier
    ["--kv-quant", "int8"],                     # transcode without a tier
    ["--prefill-budget", "16"],                 # budget without the scheduler
    ["--fault-seed", "3"],                      # seed without a plan
    ["--fault-plan", "/nonexistent/plan.json"],  # unreadable plan
    ["--tp", "2", "--no-paged-attention"],      # TP needs the paged plane
]


@pytest.mark.parametrize("extra", BAD_FLAG_COMBOS,
                         ids=[" ".join(c) for c in BAD_FLAG_COMBOS])
def test_launch_rejects_bad_flag_combos(extra):
    """Validation runs before any model build, so a bad combo exits 2
    in milliseconds instead of failing (or silently no-opting) minutes
    into a run."""
    from repro.launch.serve import serve_main
    argv = ["--arch", "qwen2_7b", "--smoke", "--requests", "2",
            "--slots", "1", "--max-seq", "32", "--cache-kb", "64",
            "--max-new", "2", "--policy", "lerc"] + extra
    with pytest.raises(SystemExit) as exc:
        serve_main(argv)
    assert exc.value.code == 2


def test_fault_plan_json_contract(tmp_path):
    p = tmp_path / "plan.json"
    p.write_text('{"seed": 7, "shard_crashes": [[4.0, 0]], '
                 '"bus_faults": [{"channel": "status", "drop_p": 0.2}], '
                 '"promotion_timeout": null}')
    plan = FaultPlan.from_json(str(p))
    assert plan.seed == 7 and plan.shard_crashes == ((4.0, 0),)
    assert plan.bus_faults[0].drop_p == 0.2
    assert plan.promotion_timeout == float("inf")
    assert not plan.empty
    assert FaultPlan().empty
    p.write_text('{"shard_crashez": []}')
    with pytest.raises(ValueError, match="shard_crashez"):
        FaultPlan.from_json(str(p))
    # capped exponential backoff for failover re-admission
    plan = FaultPlan(retry_backoff=0.5, retry_backoff_cap=4.0)
    assert [plan.backoff(k) for k in (1, 2, 3, 4, 5)] == \
        [0.5, 1.0, 2.0, 4.0, 4.0]


# ---------------------------------------------------------------------------
# satellite: deterministic disk-pool teardown
# ---------------------------------------------------------------------------

def test_disk_pool_close_unlinks_files(model):
    cfg, params = model
    blk = _blk(cfg, params)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        store = TieredKVStore(6 * blk, "lerc", block_tokens=BT,
                              host_capacity_bytes=2 * blk,
                              disk_capacity_bytes=64 * blk, disk_dir=d)
        eng = ServeEngine(cfg, params, max_slots=1, max_seq=96,
                          store=store, prefill_chunk=BT)
        for r in workload(cfg.vocab, n_requests=6):
            eng.submit(r, max_new=MAX_NEW)
            eng.run()
        pool = store.disk_pool
        assert pool._paths and all(os.path.exists(p) for p in pool._paths)
        paths = list(pool._paths)
        eng.close()                    # cascades store.close -> pool.close
        assert pool.closed
        assert not any(os.path.exists(p) for p in paths)
        eng.close()                    # idempotent


# ---------------------------------------------------------------------------
# (5) simulator: crash-as-restart, lineage recompute, exact makespan
# ---------------------------------------------------------------------------

def _chain_dag(n_tasks, block_size):
    dag = JobDAG()
    dag.add_block(BlockMeta("src", block_size, "src", 0))
    prev = "src"
    for i in range(n_tasks):
        out = f"b{i}"
        dag.add_block(BlockMeta(out, block_size, "chain", i))
        dag.add_task(TaskSpec(id=f"t{i}", inputs=(prev,), output=out,
                              job="chain"))
        prev = out
    return dag


SIZE = 10 * 2 ** 20


def test_sim_empty_plan_identical():
    hw = HardwareModel(cache_bytes=8 * SIZE)
    results = []
    for faults in (None, FaultPlan()):
        sim = ClusterSim(2, hw, faults=faults)
        sim.submit(_chain_dag(6, SIZE))
        results.append(sim.run())
    base, empty = results
    assert empty.makespan == base.makespan
    assert empty.metrics.as_dict() == base.metrics.as_dict()
    assert empty.messages.as_dict() == base.messages.as_dict()
    assert empty.task_runtimes == base.task_runtimes


def test_sim_worker_crash_exact_makespan_delta():
    """One worker, a chain job, a crash at t: the restart recomputes the
    WHOLE chain from scratch (every block was on the lost worker), so the
    faulted makespan is *exactly* ``t + clean_makespan`` — the recompute
    is charged to the clock, not absorbed. Replica coherence is proven
    inside ``run`` (verify_replicas covers the crashed run too)."""
    hw = HardwareModel(cache_bytes=8 * SIZE)
    sim = ClusterSim(1, hw)
    sim.submit(_chain_dag(4, SIZE))
    clean = sim.run()

    crash_t = clean.makespan / 2
    sim_f = ClusterSim(1, hw,
                       faults=FaultPlan(worker_crashes=((crash_t, 0),)))
    sim_f.submit(_chain_dag(4, SIZE))
    fault = sim_f.run()
    assert sim_f.worker_crashes_fired == 1
    assert fault.makespan == pytest.approx(crash_t + clean.makespan)
    # the injector's recovery ledger saw the loss and the recompute
    assert sim_f.faults.counters["fault.worker_crash"] == 1
    assert sim_f.faults.counters["recover.lost_blocks"] > 0


def test_sim_crash_out_of_range_worker_never_fires():
    """A crash scheduled on a worker index outside the cluster is ignored
    (claimed once, fired never) and the run matches the clean one."""
    hw = HardwareModel(cache_bytes=8 * SIZE)
    sim = ClusterSim(1, hw)
    sim.submit(_chain_dag(4, SIZE))
    clean = sim.run()
    sim_f = ClusterSim(1, hw,
                       faults=FaultPlan(worker_crashes=((0.1, 7),)))
    sim_f.submit(_chain_dag(4, SIZE))
    fault = sim_f.run()
    assert sim_f.worker_crashes_fired == 0
    assert fault.makespan == clean.makespan


# ---------------------------------------------------------------------------
# (6) on_lost / on_task_undone vs the rebuild() oracle
# ---------------------------------------------------------------------------

def test_on_lost_matches_rebuild_oracle():
    """Drive a DagState through done/lost/undone transitions and check
    the incremental counters against a from-scratch rebuild at every
    step (the crash path leans on exactly these transitions)."""
    dag = _chain_dag(4, 1)
    state = DagState(dag)

    def check():
        oracle = DagState(dag, materialized=set(state.materialized),
                          cached=set(state.cached),
                          done_tasks=set(state.done_tasks))
        assert state.ref_count == oracle.ref_count
        assert state.eff_ref_count == oracle.eff_ref_count
        assert {t: m for t, m in state.missing.items()
                if oracle.missing.get(t) != m} == {}

    state.on_materialized("src")
    check()
    for i in range(4):
        state.on_materialized(f"b{i}")       # marks t{i} done too
        check()
    # crash loses b1 and b2: producers resurrect, consumers stop counting
    # the unmaterialized inputs as "missing"
    for b in ("b1", "b2"):
        state.on_lost(b)
        check()
    assert "t1" not in state.done_tasks and "t2" not in state.done_tasks
    # recompute them (lineage order) and reconverge
    for b in ("b1", "b2"):
        state.on_materialized(b)
        check()
    assert state.done_tasks == {f"t{i}" for i in range(4)}

"""Unit tests for the coordination plane (core.coordination): incremental
peer profiles, the two-channel accounting, silent-vs-reported evictions
with re-arming reloads, and the sharded frontend's routing function."""
import pytest

from repro.core import (BlockMeta, DagState, JobDAG, TaskSpec, build_cluster)
from repro.core.coordination import LERC_KINDS, payload_nbytes
from repro.serve import route_prefix


def _job(job_id, tasks):
    """tasks: list of (task_name, inputs, output). Blocks auto-created."""
    dag = JobDAG()
    seen = set()
    for i, (name, inputs, output) in enumerate(tasks):
        for b in list(inputs) + [output]:
            if b not in seen:
                dag.add_block(BlockMeta(b, 1, job_id, len(seen)))
                seen.add(b)
        dag.add_task(TaskSpec(f"{job_id}.{name}", tuple(inputs), output,
                              job=job_id))
    return dag


def test_peer_profile_is_incremental_delta():
    """The second job's profile broadcast carries only its NEW blocks and
    tasks; replicas extend their DAG incrementally (no rebuild) and agree
    with a from-scratch oracle."""
    master, workers, bus = build_cluster(n_workers=2)
    job1 = _job("j1", [("t0", ["a", "b"], "x")])
    job2 = _job("j2", [("t0", ["a", "x"], "y")])     # reuses j1's blocks
    master.submit_job(job1)
    master.submit_job(job2)

    profiles = [m for m in bus.log if m.kind == "peer_profile"]
    assert len(profiles) == 2 * 2                    # 2 jobs x 2 workers
    blocks2, tasks2 = profiles[-1].payload
    assert {b.id for b in blocks2} == {"y"}          # delta only
    assert {t.id for t in tasks2} == {"j2.t0"}

    oracle = DagState(master.dag)
    for w in workers:
        for b in master.dag.blocks:
            assert w.state.ref_count.get(b, 0) == oracle.ref_count[b]
            assert w.state.eff_ref_count.get(b, 0) == oracle.eff_ref_count[b]
        assert set(w.dag.blocks) == set(master.dag.blocks)
        assert set(w.dag.tasks) == set(master.dag.tasks)


def test_replica_exists_before_any_job():
    """A tracker owns an (empty) DAG + state from construction, so a cache
    manager can be built over the replica before the first job arrives."""
    _, workers, _ = build_cluster(n_workers=1)
    assert workers[0].state.ref_count == {}
    assert not workers[0].dag.blocks


def test_bus_byte_accounting():
    """payload_bytes sums every message's serialized payload; lerc_bytes
    restricts to the LERC channel (profiles + eviction reports/bcasts)."""
    master, workers, bus = build_cluster(n_workers=3)
    master.submit_job(_job("j", [("t", ["a", "b"], "x")]))
    for b in ("a", "b"):
        workers[0].report_status("materialized", b)
    workers[0].local_eviction("a")

    assert bus.stats.payload_bytes == sum(m.nbytes for m in bus.log)
    assert bus.stats.lerc_bytes == sum(m.nbytes for m in bus.log
                                       if m.kind in LERC_KINDS)
    assert 0 < bus.stats.lerc_bytes < bus.stats.payload_bytes
    assert bus.stats.point_to_point == len(bus.log)
    # the estimate is deterministic (it feeds reproducible benchmarks)
    assert payload_nbytes(("evicted", "a")) == payload_nbytes(("evicted", "a"))


def test_eviction_protocol_rearms_after_reload():
    """§III-C re-arming: group complete -> evict peer (1 broadcast) ->
    evict second peer (silent) -> reload both (complete again) -> evict
    (1 broadcast). Exactly one broadcast per completeness flip."""
    master, workers, bus = build_cluster(n_workers=2)
    master.submit_job(_job("j", [("t", ["a", "b"], "x")]))
    w0 = workers[0]

    for b in ("a", "b"):
        w0.report_status("materialized", b)
    assert w0.local_eviction("a")              # complete -> flip
    assert bus.stats.eviction_broadcasts == 1
    assert not w0.local_eviction("b")          # already incomplete: silent
    assert bus.stats.eviction_broadcasts == 1
    for b in ("a", "b"):
        w0.report_status("materialized", b)    # reload: complete again
    assert w0.local_eviction("b")              # flip again
    assert bus.stats.eviction_broadcasts == 2
    assert bus.stats.eviction_reports == 2


def test_status_relay_covers_silent_evictions():
    """The legacy status channel must propagate evictions that are silent
    on the LERC channel, or replicas mis-label groups after a reload:
    evict c (flip), evict b (silent), reload c -> the group is STILL
    incomplete (b is gone) and every replica must know it."""
    master, workers, bus = build_cluster(n_workers=3)
    master.submit_job(_job("j", [("t", ["b", "c"], "x")]))
    w0 = workers[0]
    for blk in ("b", "c"):
        w0.report_status("materialized", blk)

    w0.local_eviction("c")                     # flip: b,c group breaks
    w0.local_eviction("b")                     # silent on the LERC channel
    w0.report_status("materialized", "c")      # reload c only
    oracle = DagState(master.dag, materialized={"b", "c"}, cached={"c"})
    for w in workers:
        for blk in master.dag.blocks:
            assert w.state.eff_ref_count.get(blk, 0) == \
                oracle.eff_ref_count[blk]
        assert w.state.cached == {"c"}
    assert bus.stats.eviction_broadcasts == 1


# --------------------------------------------------------------------------
# Sharded-frontend routing
# --------------------------------------------------------------------------

def test_route_prefix_is_stable_and_affine():
    """Same prefix -> same shard, across calls and across (restarted)
    processes: the digest is unsalted, so the mapping is a pure function
    of the tokens. Requests sharing a first block co-locate."""
    prompt = list(range(40))
    for n_shards in (1, 2, 4, 7):
        k = route_prefix(prompt, n_shards, 16)
        assert 0 <= k < n_shards
        assert route_prefix(prompt, n_shards, 16) == k
        # suffix does not affect routing (prefix affinity)
        assert route_prefix(prompt[:16] + [999, 123], n_shards, 16) == k
    # pinned values guard the mapping against accidental change (a silent
    # remap would cold-start every shard's cache on upgrade)
    assert route_prefix(list(range(40)), 4, 16) == \
        route_prefix(list(range(16)), 4, 16)


def test_route_prefix_spreads_families():
    """Distinct prefix families should not all collapse onto one shard."""
    shards = {route_prefix([f, f + 1, f + 2], 4, 16) for f in range(64)}
    assert len(shards) == 4


def test_route_prefix_short_prompt():
    """Prompts shorter than one block route on the whole prompt, still
    deterministically."""
    assert route_prefix([5], 3, 16) == route_prefix([5], 3, 16)
    assert route_prefix([], 3, 16) == route_prefix([], 3, 16)

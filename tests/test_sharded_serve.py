"""Sharded serve tier: K-shard generation must be token-identical to the
single engine under prefix-affinity routing, and every shard's eviction
log must match the coordination-plane replicas (the bus carried the whole
truth about residency, references and effective references)."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params, model_spec
from repro.serve import PrefixStore, ServeEngine, ShardedFrontend

BT = 8          # block_tokens
PROMPT = 32     # uniform prompt length (4 blocks)
MAX_NEW = 4
SHARDS = (1, 2, 4)


@pytest.fixture(scope="module")
def model():
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    return cfg, params


def workload(vocab, n_requests=12, n_families=4, seed=7):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, PROMPT - BT))
                for _ in range(n_families)]
    return [prefixes[i % n_families]
            + list(rng.integers(0, vocab, BT)) for i in range(n_requests)]


def capacity(cfg, params):
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    return probe._block_nbytes() * 10           # < working set -> evictions


def _run_frontend(cfg, params, n_shards, reqs, per_shard_cap,
                  policy="lerc", **kwargs):
    fe = ShardedFrontend(cfg, params, n_shards, max_slots=1, max_seq=64,
                         capacity_bytes=per_shard_cap, policy=policy,
                         block_tokens=BT, **kwargs)
    out = [fe.submit(r, max_new=MAX_NEW)[1] for r in reqs]
    fe.run()
    return fe, out


def test_shards_token_identical(model):
    """--shards {1,2,4} produce token-identical generations; at K=1 the
    frontend is op-for-op the single engine (same eviction log and prefix
    reuse), and every run leaves all replicas coherent."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    cap = capacity(cfg, params)

    single = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                         store=PrefixStore(cap, "lerc", block_tokens=BT))
    sreqs = [single.submit(r, max_new=MAX_NEW) for r in reqs]
    single.run()
    assert single.store.evictions > 0, "workload produced no pressure"

    for n_shards in SHARDS:
        fe, freqs = _run_frontend(cfg, params, n_shards, reqs, cap)
        assert [r.generated for r in freqs] == \
            [r.generated for r in sreqs], f"shards={n_shards}"
        fe.verify_replicas()
        if n_shards == 1:
            assert fe.shards[0].store.eviction_log == \
                single.store.eviction_log
            assert [r.prefill_skipped for r in freqs] == \
                [r.prefill_skipped for r in sreqs]


def test_per_shard_eviction_logs_match_replicas(model):
    """Each shard's store eviction log must appear, namespaced and in
    order, in EVERY tracker's replica log — cross-shard evictions reached
    every peer — and per-shard replica counters must be bit-identical to
    the shard's own store state."""
    cfg, params = model
    reqs = workload(cfg.vocab, n_requests=16, seed=11)
    # tight per-shard budget so every shard actually evicts
    per_shard_cap = capacity(cfg, params) // 2
    n_shards = 2
    fe, _ = _run_frontend(cfg, params, n_shards, reqs, per_shard_cap,
                          record_eviction_log=True)

    total_evictions = 0
    for k, eng in enumerate(fe.shards):
        log = [f"s{k}:{b}" for b in eng.store.eviction_log]
        total_evictions += len(log)
        for tr in fe.trackers:
            replica_view = [b for b in tr.eviction_log
                            if b.startswith(f"s{k}:")]
            assert replica_view == log, \
                f"shard {k} log diverged in {tr.name}"
    assert total_evictions > 0, "workload produced no pressure"

    fe.verify_replicas()     # residency + rc/erc bit-identity per shard

    # protocol shape: one broadcast per report, both bounded by evictions
    s = fe.bus.stats
    assert s.eviction_broadcasts == s.eviction_reports
    assert s.eviction_broadcasts <= total_evictions
    assert s.peer_profile_broadcasts == len(reqs)
    assert s.lerc_bytes > 0 and s.payload_bytes > s.lerc_bytes


def test_protocol_level_follows_store_policy(model):
    """Matching the sim's deployment rule: a DAG-oblivious shard ships
    ZERO LERC traffic (no peer profiles, no eviction reports/broadcasts),
    a DAG-aware-but-completeness-oblivious one ships profiles only, and
    in both cases the legacy status channel keeps replicas
    residency-coherent."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    cap = capacity(cfg, params)

    single = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                         store=PrefixStore(cap, "lru", block_tokens=BT))
    sreqs = [single.submit(r, max_new=MAX_NEW) for r in reqs]
    single.run()

    fe, freqs = _run_frontend(cfg, params, 2, reqs, cap, policy="lru")
    s = fe.bus.stats
    assert s.peer_profile_broadcasts == 0
    assert s.eviction_reports == 0 and s.eviction_broadcasts == 0
    assert s.lerc_bytes == 0
    assert s.point_to_point > 0 and s.payload_bytes > 0
    assert sum(e.store.evictions for e in fe.shards) > 0
    fe.verify_replicas()                  # residency coherent without DAG
    assert [r.generated for r in freqs] == [r.generated for r in sreqs]

    # lrc: uses_dag but not uses_completeness -> profiles, no reports
    fe_lrc, _ = _run_frontend(cfg, params, 2, reqs, cap, policy="lrc")
    s = fe_lrc.bus.stats
    assert s.peer_profile_broadcasts == len(reqs)
    assert s.eviction_reports == 0 and s.eviction_broadcasts == 0
    fe_lrc.verify_replicas()


def test_affinity_routing_preserves_prefix_reuse(model):
    """Same-family requests land on one shard, so sharding must not lose
    prefix-cache hits: with ample capacity, total skipped prefill tokens
    equal the single engine's at every K."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    single = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                         store=PrefixStore(1 << 30, "lerc", block_tokens=BT))
    for r in reqs:
        single.submit(r, max_new=MAX_NEW)
    single.run()
    for n_shards in SHARDS:
        fe, _ = _run_frontend(cfg, params, n_shards, reqs, 1 << 30)
        assert sum(e.prefill_tokens_skipped for e in fe.shards) == \
            single.prefill_tokens_skipped, f"shards={n_shards}"

"""Observability PR tests.

The obs contract, each clause with its own test below:

* **zero-overhead-when-off** — an engine (paged / tiered / sharded /
  tensor-parallel) with no recorder attached is *bit-identical* to one
  that was never instrumented: same tokens, same eviction logs, same
  metrics dicts;
* **attribution conservation** — ``sum(ineffective_by_cause.values())
  == hits - effective_hits`` structurally, under any interleaving of
  ``record_access`` and ``merge``, and on real store/sim runs;
* **field-derived aggregation** — ``CacheMetrics``/``MessageStats``
  ``merge``/``as_dict`` cover *every* dataclass field (the
  hand-maintained copies they replaced silently dropped new counters);
* **exact size cache** — the bus's shape-keyed payload size cache
  changes no byte counter vs. pickling every payload from scratch, and
  stats level ``"counts"`` zeroes bytes without touching counts;
* **trace-as-source-of-truth** — ``benchmarks.trace_report``
  reconstructs ``latency_stats`` from the trace file alone, key-for-key.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from benchmarks.trace_report import check as trace_check
from benchmarks.trace_report import (ineffective_causes, latency_from_trace,
                                     tier_flows)
from repro import configs
from repro.core import CacheMetrics, MessageStats, build_cluster
from repro.core.coordination import Message, MessageBus, payload_nbytes
from repro.models import init_params, model_spec
from repro.models.common import ModelConfig
from repro.obs import TraceRecorder
from repro.serve import (BudgetedScheduler, PrefixStore, ServeEngine,
                         ShardedFrontend, TieredKVStore, TracedRequest,
                         latency_stats, play_trace)
from repro.sharding import serve_tp_context

BT = 8          # block_tokens
PROMPT = 32     # uniform prompt length (4 blocks)
MAX_NEW = 4


# ---------------------------------------------------------------------------
# metrics dataclasses: field-derived merge / as_dict (satellite 1)
# ---------------------------------------------------------------------------
def _fill(obj, base, step=7):
    """Distinct value per field so any dropped/crossed field is caught."""
    v = base
    for f in dataclasses.fields(obj):
        if isinstance(getattr(obj, f.name), dict):
            setattr(obj, f.name, {"a": v, "b": v + 1})
        else:
            setattr(obj, f.name, v)
        v += step
    return obj


@pytest.mark.parametrize("cls", [CacheMetrics, MessageStats])
def test_merge_covers_every_field(cls):
    a, b = _fill(cls(), 1), _fill(cls(), 1000, step=13)
    for f in dataclasses.fields(b):        # asymmetric dict keys too
        if isinstance(getattr(b, f.name), dict):
            setattr(b, f.name, {"b": 2, "c": 5})
    snap_a, snap_b = dataclasses.asdict(a), dataclasses.asdict(b)
    m = a.merge(b)
    for f in dataclasses.fields(cls):
        va, vb, vm = getattr(a, f.name), getattr(b, f.name), getattr(m, f.name)
        if isinstance(va, dict):
            assert vm == {k: va.get(k, 0) + vb.get(k, 0)
                          for k in set(va) | set(vb)}, f.name
            vm["mutate"] = 1               # merged dicts are fresh objects
            assert "mutate" not in va and "mutate" not in vb
        else:
            assert vm == va + vb, f.name
    # merge never mutates its operands
    assert dataclasses.asdict(a) == snap_a
    assert dataclasses.asdict(b) == snap_b


@pytest.mark.parametrize("cls", [CacheMetrics, MessageStats])
def test_as_dict_covers_every_field(cls):
    obj = _fill(cls(), 3)
    d = obj.as_dict()
    for f in dataclasses.fields(cls):
        assert d[f.name] == getattr(obj, f.name), f.name
    # dict-valued fields are copied, not aliased
    for f in dataclasses.fields(cls):
        if isinstance(getattr(obj, f.name), dict):
            d[f.name]["mutate"] = 1
            assert "mutate" not in getattr(obj, f.name)
    if cls is CacheMetrics:
        assert d["hit_ratio"] == obj.hit_ratio
        assert d["effective_hit_ratio"] == obj.effective_hit_ratio


# ---------------------------------------------------------------------------
# effective-hit attribution (tentpole analytic)
# ---------------------------------------------------------------------------
def test_record_access_attribution_conserves():
    """Every ineffective hit lands in exactly one bucket — randomized
    interleavings plus a merge cannot break the conservation law."""
    causes = ["evicted", "host", "disk", "never_cached", None]
    rng = np.random.default_rng(0)
    parts = []
    for seed in range(3):
        m = CacheMetrics()
        for _ in range(200):
            hit = bool(rng.integers(2))
            eff = hit and bool(rng.integers(2))
            m.record_access(hit, eff, cause=None if eff or not hit
                            else causes[int(rng.integers(len(causes)))])
        m.check_attribution()
        assert sum(m.ineffective_by_cause.values()) == \
            m.hits - m.effective_hits
        parts.append(m)
    merged = parts[0].merge(parts[1]).merge(parts[2])
    merged.check_attribution()
    assert "unattributed" in merged.ineffective_by_cause


def test_record_access_rejects_impossible_combinations():
    with pytest.raises(ValueError):
        CacheMetrics().record_access(hit=False, effective=True)
    with pytest.raises(ValueError):
        CacheMetrics().record_access(hit=True, effective=True, tier=1)
    # an effective hit never grows a cause bucket, even if one is passed
    m = CacheMetrics()
    m.record_access(hit=True, effective=True, cause="evicted")
    assert m.ineffective_by_cause == {}
    m.check_attribution()


def test_check_attribution_catches_drift():
    m = CacheMetrics()
    m.record_access(hit=True, effective=False, cause="evicted")
    m.check_attribution()
    m.ineffective_by_cause["evicted"] += 1
    with pytest.raises(AssertionError):
        m.check_attribution()


# ---------------------------------------------------------------------------
# bus payload sizing: exact shape cache + stats levels (satellite 2)
# ---------------------------------------------------------------------------
def test_bus_size_cache_is_exact():
    """Byte counters with the shape cache == pickling every payload from
    scratch, across cache hits, magnitude-class edges, and every bail-out
    path (wide ints, long tuples, nesting, identity-duplicate strings)."""
    bus = MessageBus(record_log=True)
    bus.register("sink", lambda m: None)
    dup = "same-object"
    payloads = [
        ("evicted", "b1"), ("evicted", "b2"),        # cached shape, reused
        ("evicted", "a-much-longer-block-name"),     # different byte length
        ("hit", "b1"), ("é", "b1"),                  # utf-8 len != str len
        (0, 255), (256, 65535),                      # BININT1 / BININT2
        (65536, -1), (-2 ** 31, 2 ** 31 - 1),        # BININT edges
        (2 ** 40, 3), (-(2 ** 33),),                 # beyond int32 -> bail
        (1.5, -2.75), (True, False), (None,),
        ("k", 1, 2.0, None),                         # 4-tuple, mixed
        ("k", 1, 2.0, None, True),                   # 5-tuple -> bail
        (("nested",), "x"),                          # nested -> bail
        (dup, dup),                                  # pickle memo -> bail
        ("aa", "ab"),                                # same shape as ("hit",..)?
    ]
    for p in payloads:
        bus.send(Message("status", p, src="t", dst="sink"))
    assert bus._size_cache, "no payload shape ever hit the cache"
    for m in bus.log:
        assert m.nbytes == payload_nbytes(m.payload), m.payload
    assert bus.stats.payload_bytes == \
        sum(payload_nbytes(m.payload) for m in bus.log)


def _drive_cluster(stats_level):
    """Real protocol traffic: a job submit (peer-profile broadcast),
    status relays, and an eviction report/broadcast round-trip."""
    from repro.core import BlockMeta, JobDAG, TaskSpec

    master, workers, bus = build_cluster(2, record_log=False,
                                         stats_level=stats_level)
    job = JobDAG()
    for i in range(4):
        job.add_block(BlockMeta(id=f"b{i}", size=10, dataset="d", index=i))
    job.add_block(BlockMeta(id="out", size=10, dataset="d", index=9))
    job.add_task(TaskSpec(id="t0", inputs=("b0", "b1", "b2", "b3"),
                          output="out", job="j"))
    master.submit_job(job)
    for i in range(4):
        workers[0].report_status("materialized", f"b{i}")
    workers[0].local_eviction("b0")
    return bus.stats


def test_stats_level_counts_zeroes_bytes_only():
    full, counts = _drive_cluster("full"), _drive_cluster("counts")
    assert full.payload_bytes > 0 and full.lerc_bytes > 0
    assert counts.payload_bytes == 0 and counts.lerc_bytes == 0
    for f in dataclasses.fields(MessageStats):
        if f.name not in ("payload_bytes", "lerc_bytes"):
            assert getattr(counts, f.name) == getattr(full, f.name), f.name
    with pytest.raises(ValueError):
        MessageBus(stats_level="verbose")


# ---------------------------------------------------------------------------
# TraceRecorder: ring bound, export shape, timebases
# ---------------------------------------------------------------------------
def test_trace_ring_drops_oldest_and_counts():
    tr = TraceRecorder(limit=10)
    for i in range(50):
        tr.instant(f"e{i}", "t", 0, 0)
    assert len(tr.events) == 10
    assert tr.n_emitted == 50 and tr.n_dropped == 40
    names = [e["name"] for e in tr.export()["traceEvents"]
             if e["ph"] != "M"]
    assert names == [f"e{i}" for i in range(40, 50)]


def test_export_shape_and_timebases():
    tr = TraceRecorder()
    tr.label(0, "proc", tid=2)          # tid 2 -> "store" lane name
    tr.vt = 2.0
    tr.instant("a", "c", 0, 2, args={"k": (1, 2)})
    with tr.span("s", "c", 0, 2):
        pass
    tr.begin_async("req", "0:1", "request", vt=1.5)
    tr.end_async("req", "0:1", "request", args={"rid": 1})
    doc = tr.export()
    json.dumps(doc)                     # strict-JSON-serializable
    evs = doc["traceEvents"]
    # metadata first, with the default lane name
    assert evs[0]["ph"] == "M" and evs[0]["args"]["name"] == "proc"
    assert {"name": "store"} == evs[1]["args"]
    by_name = {e["name"]: e for e in evs if e["ph"] not in ("M", "b", "e")}
    assert by_name["a"]["s"] == "t"               # instants are scoped
    assert by_name["a"]["args"]["k"] == [1, 2]    # jsonable'd tuple
    assert "dur" in by_name["s"]                  # X events carry dur
    asy = [e for e in evs if e["name"] == "req"]
    assert [e["ph"] for e in asy] == ["b", "e"]
    assert all(e["id"] == "0:1" for e in asy)
    # virtual timebase: ts is the embedder clock in ms -> us
    virt = tr.export(timebase="virtual")
    va = [e for e in virt["traceEvents"] if e["name"] == "a"][0]
    assert va["ts"] == pytest.approx(2.0 * 1e3)
    vb = [e for e in virt["traceEvents"] if e["ph"] == "b"][0]
    assert vb["ts"] == pytest.approx(1.5 * 1e3)   # vt= backdating
    assert virt["otherData"]["timebase"] == "virtual"
    with pytest.raises(ValueError):
        tr.export(timebase="cpu")


# ---------------------------------------------------------------------------
# tracing-off bit-identity across the serve substrates (satellite 4)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model():
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    return cfg, params


def workload(vocab, n_requests=8, n_families=3, seed=7):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, PROMPT - BT))
                for _ in range(n_families)]
    return [prefixes[i % n_families]
            + list(rng.integers(0, vocab, BT)) for i in range(n_requests)]


def _block_nbytes(cfg, params):
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1, paged=True)
    return probe._block_nbytes()


def _run_mode(cfg, params, reqs, mode, recorder=None):
    blk = _block_nbytes(cfg, params)
    if mode == "sharded":
        fe = ShardedFrontend(cfg, params, 2, max_slots=2, max_seq=64,
                             capacity_bytes=blk * 5, policy="lerc",
                             block_tokens=BT, prefill_chunk=8, paged=True)
        if recorder is not None:
            fe.attach_trace(recorder)
        rs = [fe.submit(r, max_new=MAX_NEW)[1] for r in reqs]
        fe.run()
        logs = [e.store.eviction_log for e in fe.shards]
        return [r.generated for r in rs], logs, fe.metrics()
    st = (TieredKVStore(blk * 6, "lerc", block_tokens=BT,
                        host_capacity_bytes=blk * 64)
          if mode == "tiered"
          else PrefixStore(blk * 10, "lerc", block_tokens=BT))
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64, store=st,
                      prefill_chunk=8, paged=True)
    if recorder is not None:
        eng.attach_trace(recorder)
    rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
    eng.run()
    logs = [st.eviction_log]
    if mode == "tiered":
        logs.append(st.host_eviction_log)
    return [r.generated for r in rs], logs, eng.metrics()


# event names every traced run of the mode must produce — doubles as a
# regression net for the instrumentation sites themselves
_EXPECT_EVENTS = {
    "paged": {"step", "dispatch", "store.lookup", "store.insert",
              "store.evict", "sched.admit", "req"},
    "tiered": {"step", "store.lookup", "store.demote", "store.promote",
               "req"},
    "sharded": {"step", "store.lookup", "req", "bus.status",
                "bus.status_report", "bus.peer_profile"},
}


@pytest.mark.parametrize("mode", ["paged", "tiered", "sharded"])
def test_tracing_off_bit_identity(model, mode):
    """The same workload with and without a recorder attached: token-
    identical generations, bit-identical eviction logs, equal metrics
    dicts. Tracing observes; it never participates."""
    cfg, params = model
    reqs = workload(cfg.vocab, n_requests=10, n_families=2, seed=3)
    base_gens, base_logs, base_m = _run_mode(cfg, params, reqs, mode)
    assert any(base_logs), "workload produced no eviction pressure"
    rec = TraceRecorder()
    gens, logs, m = _run_mode(cfg, params, reqs, mode, recorder=rec)
    assert gens == base_gens
    assert logs == base_logs
    assert m == base_m
    names = {e["name"] for e in rec.events}
    missing = _EXPECT_EVENTS[mode] - names
    assert not missing, f"instrumentation sites went dark: {missing}"


# TP runs on a dedicated config whose 4 KV heads divide the mesh (the
# default smoke config has 1 KV head). Matches the equivalence suite's
# TP_CFG so the jit cache is shared across test files.
TP_CFG = ModelConfig(arch="tp_smoke", family="dense", n_layers=2,
                     d_model=32, n_heads=8, n_kv_heads=4, d_head=8,
                     d_ff=64, vocab=256, act="swiglu", layer_pattern="G")


@pytest.fixture(scope="module")
def tp_model():
    params = init_params(jax.random.key(0), model_spec(TP_CFG),
                         dtype=TP_CFG.dtype)
    return TP_CFG, params


def _run_tp2(cfg, params, reqs, recorder=None):
    blk = _block_nbytes(cfg, params)
    st = PrefixStore(blk * 10, "lerc", block_tokens=BT)
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64, store=st,
                      prefill_chunk=8, paged=True,
                      kv_shard=serve_tp_context(2))
    if recorder is not None:
        eng.attach_trace(recorder)
    rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
    eng.run()
    return [r.generated for r in rs], st.eviction_log, eng.metrics()


def test_tracing_off_bit_identity_tp2(tp_model):
    """Same contract on a tensor-parallel (tp=2) engine. Needs forced
    host devices — the CI TP leg runs with
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    cfg, params = tp_model
    reqs = workload(cfg.vocab)
    base = _run_tp2(cfg, params, reqs)
    rec = TraceRecorder()
    traced = _run_tp2(cfg, params, reqs, recorder=rec)
    assert traced == base
    assert rec.n_emitted > 0


# ---------------------------------------------------------------------------
# request lifecycle + trace_report reconstruction (tentpole analytics)
# ---------------------------------------------------------------------------
def test_trace_report_reconstructs_latency_stats(model):
    """The CLI's from-trace latency stats equal the live
    ``latency_stats`` key-for-key — including the shed (rejected) and
    cancelled request paths — on the deterministic virtual clock."""
    cfg, params = model
    reqs = workload(cfg.vocab, n_requests=12, seed=11)
    trace = [TracedRequest(t=0.0 if i < 6 else 0.4 * i, prompt=p,
                           max_new=MAX_NEW,
                           deadline=2.0 + 0.05 * len(p))
             for i, p in enumerate(reqs)]
    rec = TraceRecorder()
    blk = _block_nbytes(cfg, params)
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                      store=PrefixStore(blk * 10, "lerc", block_tokens=BT),
                      prefill_chunk=8, paged=True, max_queue=3,
                      scheduler=BudgetedScheduler(16))
    eng.attach_trace(rec)
    report = play_trace(eng, trace)
    assert report.rejected > 0, "no arrival was shed; widen the burst"
    doc = rec.export()
    assert trace_check(doc) == []
    assert latency_from_trace(doc["traceEvents"]) == latency_stats(report)


def test_cancel_closes_request_span(model):
    cfg, params = model
    rec = TraceRecorder()
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                      store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                      prefill_chunk=8, paged=True)
    eng.attach_trace(rec)
    reqs = workload(cfg.vocab, n_requests=2)
    r0 = eng.submit(reqs[0], max_new=16)
    eng.submit(reqs[1], max_new=MAX_NEW)
    for _ in range(3):
        eng.step()
    assert eng.cancel(r0)
    eng.run()
    ends = [e for e in rec.export()["traceEvents"]
            if e["ph"] == "e" and e["name"] == "req"]
    assert len(ends) == 2
    assert sorted(e["args"]["cancelled"] for e in ends) == [False, True]


def test_traced_tiered_run_attribution_and_flows(model):
    """On a demoting/promoting tiered run: the conservation law holds on
    the live metrics, the per-lookup ``ineffective`` args sum to the
    live ``ineffective_by_cause``, and the tier-flow edges extracted by
    the CLI agree with the store's move counters."""
    cfg, params = model
    reqs = workload(cfg.vocab, n_requests=10, n_families=2, seed=3)
    blk = _block_nbytes(cfg, params)
    st = TieredKVStore(blk * 6, "lerc", block_tokens=BT,
                       host_capacity_bytes=blk * 64)
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64, store=st,
                      prefill_chunk=8, paged=True)
    rec = TraceRecorder()
    eng.attach_trace(rec)
    for r in reqs:
        eng.submit(r, max_new=MAX_NEW)
    eng.run()
    m = st.metrics_obj                   # metrics() ran check_attribution
    eng.metrics()
    assert m.promotions > 0 and m.demotions > 0
    assert sum(m.ineffective_by_cause.values()) == \
        m.hits - m.effective_hits
    events = rec.export()["traceEvents"]
    assert ineffective_causes(events) == m.ineffective_by_cause
    flows = tier_flows(events)
    assert flows.get(("device", "host"), 0) == m.demotions
    assert sum(n for (s, d), n in flows.items() if d == "device") == \
        m.promotions
    # every store instant carries the policy's eviction key at decision
    # time — the forensic hook for "why did THIS block move"
    moves = [e for e in events
             if e["name"] in ("store.evict", "store.demote",
                              "store.promote")]
    assert moves and all("key" in e["args"] and "uid" in e["args"]
                         for e in moves)


# ---------------------------------------------------------------------------
# cluster sim: task spans on the virtual clock + attribution
# ---------------------------------------------------------------------------
def test_sim_trace_task_spans_and_attribution():
    from repro.sim import ClusterSim, HardwareModel, multi_tenant_zip

    rec = TraceRecorder()
    hw = HardwareModel(cache_bytes=4 * 2 ** 20, disk_bw=25e6)
    sim = ClusterSim(4, hw, policy="lerc", trace=rec)
    for dag, _ in multi_tenant_zip(n_jobs=2, n_blocks=16, file_mb=4,
                                   n_workers=4):
        sim.submit(dag)
    res = sim.run()                      # runs check_attribution
    m = res.metrics
    assert m.evictions > 0, "sim cache never under pressure"
    assert sum(m.ineffective_by_cause.values()) == \
        m.hits - m.effective_hits
    events = rec.export(timebase="virtual")["traceEvents"]
    tasks = [e for e in events if e["ph"] == "X" and e["cat"] == "task"]
    assert tasks
    # virtual-clock spans: ts/dur in us, 1 sim second = 1000 recorder ms
    ends = {e["ts"] + e["dur"] for e in tasks}
    assert max(ends) == pytest.approx(res.makespan * 1e6)
    assert any(e["name"].startswith("bus.") for e in events)

"""The PR 6 serve front door: deadline-aware step scheduling, the timed
trace event loop, admission control, cancellation, and device-side EOS.

Two layers of tests:

* **Pure policy units** (no model): ``BudgetedScheduler`` EDF admission
  and prefill planning — preemption past the budget, cost-equivalent
  chunk pricing under an attention-term clock, the FCFS/decode-first
  degradations, and the seeded arrival generators.
* **Engine integration** (smoke model): scheduled trace runs are
  deterministic; all three schedulers are token-invariant (scheduling
  moves latency, never text); backpressure sheds and counts; cancel
  mid-decode frees pool rows and the block table immediately (under the
  tiered store); device-side EOS at ``eos_interval=8`` truncates exactly
  like per-step checking while avoiding most host syncs.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params, model_spec
from repro.serve import (BudgetedScheduler, DecodeFirstScheduler,
                         FCFSScheduler, PrefixStore, QueueFull, Scheduler,
                         ServeEngine, StepCostModel, TieredKVStore,
                         TracedRequest, latency_stats, make_scheduler,
                         play_trace)
from repro.sim import bursty_arrivals, diurnal_arrivals, poisson_arrivals

BT = 8
PROMPT = 32
MAX_NEW = 4


# ---------------------------------------------------------------------------
# Pure policy units (no model)
# ---------------------------------------------------------------------------


class FakeReq:
    def __init__(self, rid, prompt_len, pos=0, slot=-1, deadline=None,
                 arrival=0.0):
        self.rid = rid
        self.prompt = [0] * prompt_len
        self.pos = pos
        self.slot = slot
        self.deadline = deadline
        self.arrival = arrival


def test_admission_fifo_vs_edf():
    q = [FakeReq(0, 8, deadline=None, arrival=0.0),
         FakeReq(1, 8, deadline=9.0, arrival=1.0),
         FakeReq(2, 8, deadline=4.0, arrival=2.0)]
    assert Scheduler().admit_idx(q) == 0            # FIFO
    assert FCFSScheduler().admit_idx(q) == 0
    assert BudgetedScheduler(16).admit_idx(q) == 2  # earliest deadline
    # deadlines always beat best-effort; best-effort falls back to arrival
    q2 = [FakeReq(0, 8, deadline=None, arrival=0.0),
          FakeReq(1, 8, deadline=99.0, arrival=5.0)]
    assert BudgetedScheduler(16).admit_idx(q2) == 1


def test_budgeted_plan_preempts_past_budget():
    urgent = FakeReq(1, 64, pos=0, slot=0, deadline=2.0)
    later = FakeReq(2, 64, pos=0, slot=1, deadline=8.0)
    best_effort = FakeReq(3, 64, pos=0, slot=2, deadline=None)
    prefilling = [urgent, later, best_effort]

    plan = BudgetedScheduler(32).plan_prefill(prefilling, 16, n_decode=3)
    assert plan == {0: 16, 1: 16}       # budget spent EDF; slot 2 preempted

    # a partially-prefilled urgent slot only draws what it still needs
    urgent.pos = 58
    plan = BudgetedScheduler(32).plan_prefill(prefilling, 16, n_decode=0)
    assert plan[0] == 6 and plan[1] == 16
    assert sum(plan.values()) <= 32

    # budget=0 never plans prefill (strict decode-first degradation);
    # budget=None means no cap (FCFS degradation)
    assert BudgetedScheduler(0).plan_prefill(prefilling, 16, 0) == {}
    full = BudgetedScheduler(None).plan_prefill(prefilling, 16, 0)
    assert full == {0: 6, 1: 16, 2: 16}


def test_budgeted_cost_equivalent_chunks():
    """With an attention-term clock, a chunk deep into a long context is
    charged its cost-equivalent tokens, so planned chunks shrink with
    position and the *charged* total stays within budget."""
    clock = StepCostModel(base=0.25, per_token=0.05, per_attn=0.01)
    sched = BudgetedScheduler(32, clock=clock)
    shallow = FakeReq(1, 200, pos=0, slot=0, deadline=2.0)
    deep = FakeReq(2, 200, pos=100, slot=1, deadline=1.0)

    # the deep slot is EDF-first, yet its quadratic price caps it at a
    # sliver; the leftover buys the shallow slot a *larger* chunk
    plan = sched.plan_prefill([shallow, deep], 16, n_decode=0)
    assert 0 < plan[1] < plan[0] < 16
    charged = sum(sched._eff_tokens(n, {0: 0, 1: 100}[s])
                  for s, n in plan.items())
    assert charged <= 32
    # without the attention term the same budget is flat tokens
    flat = BudgetedScheduler(32).plan_prefill([shallow, deep], 16, 0)
    assert flat == {0: 16, 1: 16}


def test_decode_first_plan():
    r = FakeReq(1, 64, pos=0, slot=0)
    assert DecodeFirstScheduler().plan_prefill([r], 16, n_decode=1) == {}
    assert DecodeFirstScheduler().plan_prefill([r], 16, n_decode=0) == \
        {0: 16}


def test_latency_stats_empty_trace_is_nan_free():
    """Zero finished requests (or a fully-shed trace) must report plain
    zeros — an empty sample used to feed NaN percentiles into the JSON
    artifact and a zero-offered trace risked dividing by zero."""
    import math

    from repro.serve.engine import Request
    from repro.serve.scheduler import TraceReport

    unfinished = Request(rid=1, prompt=[0] * 8, max_new=4)
    for report in (TraceReport(),                        # nothing offered
                   TraceReport(rejected=5),              # everything shed
                   TraceReport(requests=[unfinished])):  # nothing finished
        stats = latency_stats(report)
        for k, v in stats.items():
            assert isinstance(v, (int, float)), k
            assert math.isfinite(v), f"{k} is {v}"
        for q in (50, 95, 99):
            assert stats[f"ttft_p{q}"] == 0.0
            assert stats[f"tpot_p{q}"] == 0.0
    assert latency_stats(TraceReport())["goodput"] == 0.0
    assert latency_stats(TraceReport(rejected=5))["n_offered"] == 5


def test_make_scheduler():
    assert make_scheduler("fcfs").name == "fcfs"
    assert make_scheduler("decode-first").name == "decode-first"
    s = make_scheduler("budgeted", prefill_budget=7)
    assert isinstance(s, BudgetedScheduler) and s.prefill_budget == 7
    with pytest.raises(ValueError):
        make_scheduler("srpt")


@pytest.mark.parametrize("gen", [poisson_arrivals, bursty_arrivals,
                                 diurnal_arrivals])
def test_arrival_generators(gen):
    a = gen(64, 2.0, seed=3)
    b = gen(64, 2.0, seed=3)
    assert np.array_equal(a, b)                     # seeded-deterministic
    assert len(a) == 64
    assert np.all(np.diff(a) >= 0) and a[0] >= 0    # time-sorted
    assert not np.array_equal(a, gen(64, 2.0, seed=4))


# ---------------------------------------------------------------------------
# Engine integration (smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                        dtype=cfg.dtype)
    return cfg, params


def _trace(vocab, n=10, rate=1.5, seed=5, deadline=4.0):
    rng = np.random.default_rng(seed)
    times = poisson_arrivals(n, rate, seed)
    prefixes = [list(rng.integers(0, vocab, PROMPT - BT)) for _ in range(2)]
    return [TracedRequest(t=float(t),
                          prompt=prefixes[i % 2]
                          + list(rng.integers(0, vocab, BT)),
                          max_new=MAX_NEW, deadline=deadline)
            for i, t in enumerate(times)]


def _engine(cfg, params, *, scheduler=None, store=None, slots=2, **kw):
    return ServeEngine(
        cfg, params, max_slots=slots, max_seq=64,
        store=store or PrefixStore(1 << 30, "lerc", block_tokens=BT),
        prefill_chunk=8, paged=True, scheduler=scheduler, **kw)


def test_scheduled_trace_deterministic(model):
    cfg, params = model
    trace = _trace(cfg.vocab)
    runs = []
    for _ in range(2):
        eng = _engine(cfg, params, scheduler=BudgetedScheduler(8))
        report = play_trace(eng, trace)
        runs.append(([r.generated for r in report.requests],
                     latency_stats(report), eng.now))
    assert runs[0] == runs[1]
    stats = runs[0][1]
    assert stats["n_offered"] == len(trace)
    assert 0.0 <= stats["goodput"] <= 1.0
    assert stats["ttft_p50"] <= stats["ttft_p95"] <= stats["ttft_p99"]


def test_schedulers_are_token_invariant(model):
    """Greedy decode + KV-exact prefix restore: *when* chunks run cannot
    change *what* they compute. All schedulers, same text."""
    cfg, params = model
    trace = _trace(cfg.vocab, n=8)
    gens = {}
    for sched in ("fcfs", "decode-first", BudgetedScheduler(8)):
        eng = _engine(cfg, params, scheduler=sched)
        report = play_trace(eng, trace)
        name = sched if isinstance(sched, str) else sched.name
        # EDF admission reorders; compare by submission (rid) order
        gens[name] = [r.generated
                      for r in sorted(report.requests,
                                      key=lambda r: r.rid)]
    assert gens["fcfs"] == gens["decode-first"] == gens["budgeted"]


def test_backpressure_sheds_and_counts(model):
    cfg, params = model
    eng = _engine(cfg, params, slots=1, max_queue=2)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, PROMPT)) for _ in range(4)]
    eng.submit(prompts[0])
    eng.submit(prompts[1])
    with pytest.raises(QueueFull):
        eng.submit(prompts[2])
    assert eng.metrics()["rejected"] == 1

    # the trace loop counts sheds instead of raising
    eng2 = _engine(cfg, params, slots=1, max_queue=1)
    trace = [TracedRequest(t=0.0, prompt=p, max_new=MAX_NEW)
             for p in prompts]
    report = play_trace(eng2, trace)
    assert report.rejected > 0
    assert report.rejected + len(report.requests) == len(trace)
    stats = latency_stats(report)
    assert stats["n_offered"] == len(trace)
    assert stats["n_rejected"] == report.rejected


def test_cancel_mid_decode_frees_rows(model):
    """Cancelling a decoding request must drop its block table and return
    its private tail rows to the pool *immediately* — under the tiered
    store, whose demotion path is sensitive to dangling references."""
    cfg, params = model
    blk_probe = _engine(cfg, params)
    blk = blk_probe._block_nbytes()
    store = TieredKVStore(blk * 6, "lerc", block_tokens=BT,
                          host_capacity_bytes=blk * 32)
    eng = _engine(cfg, params, store=store)
    rng = np.random.default_rng(1)
    victim = eng.submit(list(rng.integers(0, cfg.vocab, PROMPT)),
                        max_new=64)
    other = eng.submit(list(rng.integers(0, cfg.vocab, PROMPT)),
                       max_new=MAX_NEW)
    while victim.n_generated < 2:       # step until mid-decode
        eng.step()
    slot = victim.slot
    in_use = eng.pool.blocks_in_use
    assert eng._tables[slot], "victim holds no pool rows?"

    assert eng.cancel(victim)
    assert victim.cancelled and victim.done
    assert eng._tables[slot] == [] and eng.slots[slot] is None
    assert eng.pool.blocks_in_use < in_use      # tail rows came back
    assert len(eng.drain(victim)) >= 2          # computed tokens readable
    assert not eng.cancel(victim)               # idempotent

    eng.run()                                   # engine still consistent
    assert other.done and len(other.generated) == MAX_NEW
    m = eng.metrics()
    assert m["cancellations"] == 1
    resident = sum(1 for n in store._nodes.values() if n.resident)
    assert eng.pool.blocks_in_use <= resident + 1       # junk row


def test_device_eos_matches_per_step_checking(model):
    """Device-side EOS with a sync every 8 steps must produce the same
    truncated generations as checking every step — while skipping most
    per-step host syncs."""
    cfg, params = model
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab, PROMPT)) for _ in range(4)]

    free = _engine(cfg, params)
    frees = [free.submit(p, max_new=12) for p in prompts]
    free.run()
    # pick an EOS id this model actually emits mid-stream
    eos = frees[0].generated[4]

    gens = {}
    for interval in (1, 8):
        eng = _engine(cfg, params, eos_id=eos, eos_interval=interval)
        rs = [eng.submit(p, max_new=12) for p in prompts]
        eng.run()
        for r in rs:
            if eos in r.generated:
                assert r.generated[-1] == eos       # truncated at first EOS
                assert r.generated.count(eos) == 1
        gens[interval] = ([r.generated for r in rs],
                          eng.metrics()["host_syncs_avoided"],
                          eng.steps)
    assert gens[1][0] == gens[8][0]
    assert any(eos in g for g in gens[8][0]), "EOS never fired"
    # the interval=8 engine syncs at most every 8th step; per-step
    # checking pays a readback on every decode step
    assert gens[8][1] > gens[1][1]


def test_virtual_clock_advances_with_step_cost(model):
    cfg, params = model
    clock = StepCostModel(base=1.0, per_token=0.0)
    eng = _engine(cfg, params, clock=clock)
    rng = np.random.default_rng(4)
    eng.submit(list(rng.integers(0, cfg.vocab, PROMPT)), max_new=MAX_NEW)
    eng.run()
    assert eng.now == pytest.approx(float(eng.steps))
    m = eng.metrics()
    assert m["virtual_time"] == pytest.approx(float(eng.steps))

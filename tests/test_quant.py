"""repro.quant — the shared transcode layer under KV tiering and gradient
compression: per-block round-trip error bounds (the property the serve
token-quality gate leans on), np/jnp parity (host↔disk transcodes must
agree with the device kernels bit-for-bit), format transcoding, the
historical per-tensor gradient numerics, and the one byte-accounting
formula both train and serve report."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.train import compression

SPECS = [quant.INT8, quant.FP8]
# (n, *mid, bt, KV, D): mid = per-row leading axes (layers etc.) — absent,
# single, and multi-axis variants, trailing three always (bt, KV, D)
SHAPES = [(5, 4, 2, 6), (3, 2, 8, 1, 4), (2, 3, 2, 4, 2, 8)]
DTYPES = ["float32", "bfloat16"]


def _blocks(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    # per-block magnitude spread across orders of magnitude: the bound is
    # relative to each block's own amax, so scales must actually differ
    x = rng.standard_normal(shape) * (10.0 ** rng.uniform(-3, 2, (shape[0],)
                                      + (1,) * (len(shape) - 1)))
    return jnp.asarray(x, jnp.dtype(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_round_trip_error_bound(spec, shape, dtype):
    """|x - deq(quant(x))| <= spec.rt_bound * amax(block), element-wise,
    for every block of every (format, layout, source dtype)."""
    x = _blocks(shape, dtype, seed=hash((spec.name, shape, dtype)) & 0xFFFF)
    q, scales = quant.quantize_rows(x, spec=spec)
    assert q.shape == x.shape and q.dtype == jnp.dtype(spec.dtype)
    assert scales.shape == x.shape[:-3]
    assert scales.dtype == jnp.float32
    rt = quant.dequantize_rows(q, scales, dtype=jnp.float32)
    xf = np.asarray(x, np.float32)
    amax = np.max(np.abs(xf), axis=(-3, -2, -1), keepdims=True)
    err = np.abs(xf - np.asarray(rt))
    # 1% slack over the exact half-step bound: coarse (bf16) values land
    # on rounding ties, and the f32 divide/multiply add a few ulps
    assert np.all(err <= spec.rt_bound * amax * 1.01 + 1e-9), \
        f"max rel err {np.max(err / np.maximum(amax, 1e-12)):.5f}"


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_numpy_twins_match_jnp(spec):
    """Host/disk transcodes (numpy) and device kernels (jnp) are the same
    math: identical stored bytes; scales agree to 1 ulp (XLA lowers the
    divide to a reciprocal multiply)."""
    x = _blocks(SHAPES[1], "float32", seed=7)
    qj, sj = quant.quantize_rows(x, spec=spec)
    qn, sn = quant.quantize_blocks_np(np.asarray(x), spec)
    np.testing.assert_array_equal(np.asarray(qj), qn)
    np.testing.assert_allclose(np.asarray(sj), sn, rtol=2e-7)
    dj = quant.dequantize_rows(qj, np.asarray(sn), dtype=jnp.float32)
    dn = quant.dequantize_blocks_np(qn, sn, np.float32)
    np.testing.assert_array_equal(np.asarray(dj), dn)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_all_zero_block_round_trips_exactly(spec):
    x = jnp.zeros((2, 3, 4, 2, 2), jnp.float32)
    q, s = quant.quantize_rows(x, spec=spec)
    assert not np.any(np.asarray(q).view(np.uint8))
    np.testing.assert_array_equal(
        np.asarray(quant.dequantize_rows(q, s, dtype=jnp.float32)), 0.0)


def test_transcode_identity_and_cross_format():
    x = {"k": np.asarray(_blocks(SHAPES[0], "float32", seed=11)),
         "v": np.asarray(_blocks(SHAPES[0], "float32", seed=12))}
    q, s = (jax.tree.map(lambda b: quant.quantize_blocks_np(b, quant.INT8)[i],
                         x) for i in (0, 1))
    # same format: the identity, arrays untouched
    q2, s2 = quant.transcode_tree_np(q, s, quant.INT8, quant.INT8)
    assert q2 is q and s2 is s
    # int8 -> fp8: within the sum of both formats' bounds of the original
    q3, s3 = quant.transcode_tree_np(q, s, quant.INT8, quant.FP8)
    for leaf in jax.tree.leaves(q3):
        assert leaf.dtype == quant.FP8.dtype
    rt = jax.tree.map(lambda a, b: quant.dequantize_blocks_np(a, b,
                                                              np.float32),
                      q3, s3)
    bound = quant.INT8.rt_bound + quant.FP8.rt_bound
    for k in x:
        amax = np.max(np.abs(x[k]), axis=(-3, -2, -1), keepdims=True)
        assert np.all(np.abs(x[k] - rt[k]) <= bound * amax + 1e-9)
    # quantized -> lossless: widens to f32, no scales
    w, sw = quant.transcode_tree_np(q, s, quant.INT8, None)
    assert sw is None
    for leaf in jax.tree.leaves(w):
        assert leaf.dtype == np.float32
    # lossless -> quantized matches quantizing the source directly
    q4, s4 = quant.transcode_tree_np(x, None, None, quant.INT8)
    for k in x:
        qd, sd = quant.quantize_blocks_np(x[k], quant.INT8)
        np.testing.assert_array_equal(q4[k], qd)
        np.testing.assert_array_equal(s4[k], sd)


def test_per_tensor_matches_historical_gradient_numerics():
    """quantize_tensor/dequantize_tensor are bit-identical to the formula
    train.compression carried before the factor-out (amax/127 symmetric
    int8, 1e-12 floor) — error-feedback state files stay valid."""
    rng = np.random.default_rng(3)
    for x in (rng.standard_normal((64, 7)).astype(np.float32) * 0.03,
              np.zeros((5, 5), np.float32)):
        q, s = quant.quantize_tensor(jnp.asarray(x))
        amax = np.max(np.abs(x))
        scale = np.maximum(amax, 1e-12) / 127.0
        q_ref = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
        np.testing.assert_array_equal(np.asarray(q), q_ref)
        assert float(s) == pytest.approx(scale, rel=1e-6)
        np.testing.assert_array_equal(
            np.asarray(quant.dequantize_tensor(q, s)),
            q_ref.astype(np.float32) * np.float32(scale))


def test_compression_ratio_prices_scales_and_source_dtype():
    # exact small-block accounting: 64 f32 elements + one f32 scale
    assert quant.compression_ratio(64, np.float32) == \
        pytest.approx(256 / 68)
    # bf16 sources compress 2x-ish, not the 4x a f32-only formula claims
    assert quant.compression_ratio(64, jnp.bfloat16) == \
        pytest.approx(128 / 68)
    # scale overhead washes out at tensor scale
    assert quant.compression_ratio(1 << 20, np.float32) == \
        pytest.approx(4.0, rel=1e-4)
    assert quant.compression_ratio(64, np.float32, None) == 1.0
    # train reports through the same formula
    assert compression.compression_ratio(jnp.float32) == pytest.approx(4.0)
    assert compression.compression_ratio(jnp.float32, numel=64) == \
        pytest.approx(quant.compression_ratio(64, np.float32))
    assert compression.compression_ratio(jnp.bfloat16) == pytest.approx(2.0)


def test_get_spec_resolution():
    assert quant.get_spec(None) is None
    assert quant.get_spec("none") is None
    assert quant.get_spec("INT8") is quant.INT8
    assert quant.get_spec(quant.FP8) is quant.FP8
    with pytest.raises(ValueError):
        quant.get_spec("int4")

"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a reduced config of the same family and runs one forward
/ train / decode step on CPU with shape + no-NaN assertions."""
import jax
import jax.numpy as jnp
import pytest

# the arch sweep is the bulk of the suite's wall time (~3 min): opt-in
pytestmark = pytest.mark.slow

from repro import configs
from repro.models import (decode_step, forward, init_decode_cache,
                          init_params, loss_fn, make_dummy_batch, model_spec,
                          param_count)
from repro.models import encdec as ED
from repro.sharding import local_context
from repro.train import TrainConfig, build_train_step, make_train_state

ARCHS = configs.ARCH_IDS


@pytest.fixture(scope="module")
def smoke(request):
    return None


def _setup(arch):
    cfg = configs.get(arch, smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg), dtype=cfg.dtype)
    return cfg, params


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params = _setup(arch)
    B, S = 2, 32
    batch = make_dummy_batch(cfg, B, S)
    logits = forward(cfg, params, batch)
    S_out = S + (cfg.frontend_len if cfg.frontend == "patch_embed" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_nothing_nan(arch):
    cfg, _ = _setup(arch)
    tc = TrainConfig()
    state = make_train_state(cfg, tc)
    step = jax.jit(build_train_step(cfg, tc, local_context()))
    batch = make_dummy_batch(cfg, 2, 32)
    state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params actually moved
    state2, m2 = step(state, batch)
    assert bool(jnp.isfinite(m2["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_steps(arch):
    cfg, params = _setup(arch)
    B, S = 2, 16
    if cfg.family == "encdec":
        frames = jnp.zeros((B, cfg.frontend_len, cfg.d_model), cfg.dtype)
        enc = ED.encode(cfg, params, frames)
        cache = ED.encdec_prefill_cache(cfg, params, enc, B, S)
    else:
        cache = init_decode_cache(cfg, B, S)
    toks = jnp.ones((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = decode_step(cfg, params, cache, toks, pos)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["qwen2_7b", "gemma2_27b", "rwkv6_3b",
                                  "recurrentgemma_9b"])
def test_decode_matches_forward(arch):
    """Incremental decode must agree with the teacher-forced forward on
    the same token sequence (KV-cache correctness end-to-end)."""
    cfg, params = _setup(arch)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits_full = forward(cfg, params, {"tokens": toks}).astype(jnp.float32)
    cache = init_decode_cache(cfg, B, S)
    outs = []
    for pos in range(S):
        lg, cache = decode_step(cfg, params, cache, toks[:, pos:pos + 1],
                                pos)
        outs.append(lg[:, 0].astype(jnp.float32))
    logits_inc = jnp.stack(outs, axis=1)
    # bf16 params, fp32 softmax path: tolerance loose but meaningful
    assert float(jnp.max(jnp.abs(logits_full - logits_inc))) < 0.15, arch


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    want = {
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256_000),
        "qwen1_5_110b": (80, 8192, 64, 8, 49152, 152_064),
        "codeqwen1_5_7b": (32, 4096, 32, 32, 13440, 92_416),
        "gemma2_27b": (46, 4608, 32, 16, 36864, 256_000),
        "qwen2_7b": (28, 3584, 28, 4, 18944, 152_064),
        "paligemma_3b": (18, 2048, 8, 1, 16384, 257_216),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163_840),
        "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 8192, 202_048),
        "whisper_base": (6, 512, 8, 8, 2048, 51_865),
        "rwkv6_3b": (32, 2560, 16, 16, 8960, 65_536),
    }
    for arch, (L, d, H, KV, ff, V) in want.items():
        cfg = configs.get(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, KV, ff, V), arch
    # MoE routing parameters
    assert configs.get("moonshot_v1_16b_a3b").n_experts == 64
    assert configs.get("moonshot_v1_16b_a3b").top_k == 6
    assert configs.get("llama4_maverick_400b_a17b").n_experts == 128
    assert configs.get("llama4_maverick_400b_a17b").top_k == 1


def test_chunked_attention_equals_xla_at_model_level():
    cfg = configs.get("gemma2_27b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=jnp.float32)
    cfg32 = cfg.replace(dtype=jnp.float32)
    toks = jax.random.randint(jax.random.key(2), (2, 32), 0, cfg.vocab)
    lx = forward(cfg32.replace(attn_impl="xla"), params, {"tokens": toks})
    lc = forward(cfg32.replace(attn_impl="chunked", attn_q_chunk=16,
                               attn_kv_chunk=8), params, {"tokens": toks})
    assert float(jnp.max(jnp.abs(lx - lc))) < 1e-3

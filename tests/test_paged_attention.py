"""Paged flash-decoding kernel vs oracles, and the decode-kernel flag.

Separate from test_kernels.py so these run without hypothesis installed
(the tier-1 container has no dev extras; CI runs both).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import attention_ref


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# paged flash-decoding (block-table split-K over KV pool pages)
# ---------------------------------------------------------------------------

PAGED_CASES = [
    # (B, S, H, KV, D, bt, NW, softcap)
    (2, 1, 4, 2, 64, 8, 8, None),        # plain decode, GQA
    (3, 4, 4, 1, 64, 8, 6, None),        # prefill chunk, MQA
    (1, 8, 8, 2, 32, 4, 16, 50.0),       # chunk > bt, softcap
    (2, 3, 2, 2, 128, 16, 4, None),      # chunk not dividing bt
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_attention_matches_oracle(case):
    """The paged kernel must agree with dense attention over the logical
    cache each block table describes: materialize row b's chain
    (pages[tables[b]] flattened), then causal-attend each query token at
    its absolute position."""
    from repro.kernels import paged_decode_attention
    B, S, H, KV, D, bt, NW, softcap = case
    NB = B * NW + 3                       # pool bigger than any one table
    ks = jax.random.split(jax.random.PRNGKey(sum(case[:6])), 4)
    q = _rand(ks[0], (B, S, H, D))
    kp = _rand(ks[1], (NB, bt, KV, D))
    vp = _rand(ks[2], (NB, bt, KV, D))
    # disjoint, shuffled tables: pool row order is unrelated to position
    perm = jax.random.permutation(ks[3], NB)[:B * NW]
    tables = perm.reshape(B, NW).astype(jnp.int32)
    pos0 = jnp.array([(7 * b + 5) % (NW * bt - S) for b in range(B)],
                     jnp.int32)
    qpos = pos0[:, None] + jnp.arange(S)[None, :]
    out = paged_decode_attention(q, kp, vp, tables, qpos, softcap=softcap)
    for b in range(B):
        kc = kp[tables[b]].reshape(NW * bt, KV, D)
        vc = vp[tables[b]].reshape(NW * bt, KV, D)
        for j in range(S):
            vl = int(qpos[b, j]) + 1
            ref = attention_ref(q[b:b + 1, j:j + 1], kc[None, :vl],
                                vc[None, :vl], causal=False,
                                softcap=softcap)[0, 0]
            np.testing.assert_allclose(np.asarray(out[b, j]),
                                       np.asarray(ref),
                                       atol=3e-5, rtol=3e-5)


def test_paged_matches_plain_flash_decoding():
    """With an identity table (row i backs positions [i*bt, (i+1)*bt)) and
    S=1, the paged kernel must reproduce plain flash-decoding over the
    materialized contiguous cache."""
    from repro.kernels import decode_attention, paged_decode_attention
    B, H, KV, D, bt, NW = 2, 4, 2, 64, 8, 8
    S_cache = NW * bt
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(ks[0], (B, 1, H, D))
    kp = _rand(ks[1], (B * NW, bt, KV, D))
    vp = _rand(ks[2], (B * NW, bt, KV, D))
    tables = jnp.arange(B * NW, dtype=jnp.int32).reshape(B, NW)
    valid = jnp.array([S_cache, S_cache - 13], jnp.int32)
    out_paged = paged_decode_attention(q, kp, vp, tables,
                                       valid[:, None] - 1)
    kc = kp[tables].reshape(B, S_cache, KV, D)
    vc = vp[tables].reshape(B, S_cache, KV, D)
    out_plain = decode_attention(q[:, 0], kc, vc, valid, block_k=bt)
    np.testing.assert_allclose(np.asarray(out_paged[:, 0]),
                               np.asarray(out_plain),
                               atol=3e-5, rtol=3e-5)


def test_flash_decode_flag_matches_xla_decode_path():
    """ModelConfig.decode_kernel="flash" must route the engine's decode
    steps through the flash-decoding kernel (interpret mode here) with
    logits matching the dense-mask XLA path."""
    import jax.numpy as jnp  # noqa: F811
    from repro import configs
    from repro.models import decode_step, init_decode_cache, init_params, \
        model_spec
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), model_spec(cfg),
                         dtype=jnp.float32)
    B, S_cache = 2, 32
    tokens = jnp.array([[5], [9]], jnp.int32)
    for pos in (jnp.int32(7),                       # bulk decode
                jnp.array([3, 11], jnp.int32)):     # per-slot decode
        outs = {}
        for impl in ("xla", "flash"):
            cfg_i = cfg.replace(decode_kernel=impl, dtype=jnp.float32)
            cache = init_decode_cache(cfg_i, B, S_cache)
            logits, _ = decode_step(cfg_i, params, cache, tokens, pos)
            outs[impl] = np.asarray(logits, np.float32)
        np.testing.assert_allclose(outs["flash"], outs["xla"],
                                   atol=2e-4, rtol=2e-4)

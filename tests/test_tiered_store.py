"""Tiered KV store (PR 4 + PR 8): tier equivalence, promotion semantics,
and the transcoding ladder.

Contracts: (1) with the host tier disabled the tiered store is op-for-op
the single-tier engine — same tokens, same eviction log; (2) a
re-referenced evicted prefix is served by *promotion* — zero prefill
recompute dispatches for the demoted blocks — and promoted chains
generate token-identically to recomputed ones; (3) a sharded frontend
with tiered shards matches the single tiered engine; (4) ``kv_quant=
"none"`` is the lossless identity (tokens, logs, full metrics dict);
(5) int8 demotion stays inside a measured token-divergence budget;
(6) blocks that fell two rungs to the lossless disk tier still generate
exactly."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params, model_spec
from repro.serve import (PrefixStore, ServeEngine, ShardedFrontend,
                         TieredKVStore)

BT = 8          # block_tokens
PROMPT = 40     # uniform prompt length (5 blocks: 4 prefix + 1 suffix)
MAX_NEW = 4


@pytest.fixture(scope="module")
def model():
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    return cfg, params


def _block_bytes(cfg, params):
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    return probe._block_nbytes()


def workload(vocab, n_requests=12, n_families=4, seed=3):
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, PROMPT - BT))
                for _ in range(n_families)]
    return [prefixes[i % n_families]
            + list(rng.integers(0, vocab, BT)) for i in range(n_requests)]


def _engine(cfg, params, store):
    return ServeEngine(cfg, params, max_slots=1, max_seq=64, store=store,
                       prefill_chunk=BT)


def _serve(eng, reqs):
    out = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
    eng.run()
    return out


def test_host_tier_disabled_is_bit_identical(model):
    """host_capacity 0 (the --host-cache-kb 0 path): every op — tokens,
    eviction log, counters — identical to today's single-tier engine."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    cap = _block_bytes(cfg, params) * 8          # < working set: evictions

    plain = _engine(cfg, params, PrefixStore(cap, "lerc", block_tokens=BT))
    tiered = _engine(cfg, params,
                     TieredKVStore(cap, "lerc", block_tokens=BT,
                                   host_capacity_bytes=0))
    preqs = _serve(plain, reqs)
    treqs = _serve(tiered, reqs)

    assert plain.store.evictions > 0, "workload produced no pressure"
    assert [r.generated for r in treqs] == [r.generated for r in preqs]
    assert tiered.store.eviction_log == plain.store.eviction_log
    assert [r.prefill_skipped for r in treqs] == \
        [r.prefill_skipped for r in preqs]
    pm, tm = plain.metrics(), tiered.metrics()
    assert all(tm[k] == pm[k] for k in pm
               if k not in ("host_blocks", "host_blocks_in_use",
                            "host_high_water"))
    assert tm["demotions"] == tm["promotions"] == tm["tier1_hits"] == 0


def test_promotion_serves_evicted_prefix_without_recompute(model):
    """After device pressure demotes a family's chain, re-referencing it
    is served by promotion: the engine skips prefill for every demoted
    block (only the fresh suffix is computed) and the generated tokens
    are identical to the recompute path."""
    cfg, params = model
    blk = _block_bytes(cfg, params)
    rng = np.random.default_rng(17)
    fam_a = list(rng.integers(0, cfg.vocab, PROMPT - BT))
    others = [list(rng.integers(0, cfg.vocab, PROMPT))
              for _ in range(3)]
    suffix1 = list(rng.integers(0, cfg.vocab, BT))
    suffix2 = list(rng.integers(0, cfg.vocab, BT))

    def run_engine(host_blocks):
        store = TieredKVStore(blk * 6, "lerc", block_tokens=BT,
                              host_capacity_bytes=blk * host_blocks) \
            if host_blocks else \
            PrefixStore(blk * 6, "lerc", block_tokens=BT)
        eng = _engine(cfg, params, store)
        _serve(eng, [fam_a + suffix1])           # warm family A
        _serve(eng, others)                      # pressure demotes/evicts A
        pre_prefill = eng.prefill_tokens
        req = _serve(eng, [fam_a + suffix2])[0]  # re-reference A
        return eng, req, eng.prefill_tokens - pre_prefill

    tiered, treq, trecompute = run_engine(host_blocks=64)
    m = tiered.metrics()
    assert m["demotions"] > 0, "no device pressure"
    assert m["promotions"] >= 4, "prefix chain was not promoted"
    assert m["tier1_hits"] >= 4
    # zero prefill recompute for the demoted blocks: the 4-block shared
    # prefix is skipped entirely, only the fresh suffix is prefilled
    assert treq.prefill_skipped == PROMPT - BT
    assert trecompute == BT

    plain, preq, precompute = run_engine(host_blocks=0)
    assert precompute > BT, "recompute baseline unexpectedly warm"
    # promoted KV is exact: generation identical to the recompute path
    assert treq.generated == preq.generated


def test_tiered_sharded_matches_single(model):
    """A ShardedFrontend with tiered shards is token-identical to the
    single tiered engine, K=1 op-for-op (same eviction log), and leaves
    every coordination replica coherent across demotions/promotions."""
    cfg, params = model
    reqs = workload(cfg.vocab, n_requests=16, seed=11)
    blk = _block_bytes(cfg, params)
    # host tier smaller than the spilled working set, so the second
    # (host) eviction index and its skeleton GC run too
    cap, host_cap = blk * 8, blk * 10

    single = _engine(cfg, params,
                     TieredKVStore(cap, "lerc", block_tokens=BT,
                                   host_capacity_bytes=host_cap))
    sreqs = _serve(single, reqs)
    assert single.store.metrics_obj.demotions > 0
    assert single.store.metrics_obj.promotions > 0
    assert single.store.metrics_obj.host_evictions > 0, \
        "host tier produced no final evictions"

    for n_shards in (1, 2):
        fe = ShardedFrontend(cfg, params, n_shards, max_slots=1,
                             max_seq=64, capacity_bytes=cap, policy="lerc",
                             block_tokens=BT, prefill_chunk=BT,
                             host_capacity_bytes=host_cap)
        freqs = [fe.submit(r, max_new=MAX_NEW)[1] for r in reqs]
        fe.run()
        assert [r.generated for r in freqs] == \
            [r.generated for r in sreqs], f"shards={n_shards}"
        fe.verify_replicas()
        if n_shards == 1:
            assert fe.shards[0].store.eviction_log == \
                single.store.eviction_log
            assert fe.shards[0].store.host_eviction_log == \
                single.store.host_eviction_log
            assert [r.prefill_skipped for r in freqs] == \
                [r.prefill_skipped for r in sreqs]


def test_kv_quant_none_is_bit_identical(model):
    """The transcoding machinery set to lossless ("none", the default CLI
    value) takes the exact pre-quant paths: tokens, both eviction logs,
    and the FULL metrics dict match a default-constructed tiered store.
    Guards the contract that quantization is strictly opt-in."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    blk = _block_bytes(cfg, params)
    cap, host_cap = blk * 8, blk * 10

    base = _engine(cfg, params,
                   TieredKVStore(cap, "lerc", block_tokens=BT,
                                 host_capacity_bytes=host_cap))
    loss = _engine(cfg, params,
                   TieredKVStore(cap, "lerc", block_tokens=BT,
                                 host_capacity_bytes=host_cap,
                                 kv_quant="none"))
    breqs = _serve(base, reqs)
    lreqs = _serve(loss, reqs)

    assert base.store.metrics_obj.demotions > 0, "no tier traffic"
    assert base.store.metrics_obj.promotions > 0
    assert [r.generated for r in lreqs] == [r.generated for r in breqs]
    assert loss.store.eviction_log == base.store.eviction_log
    assert loss.store.host_eviction_log == base.store.host_eviction_log
    assert loss.metrics() == base.metrics()
    assert loss.metrics()["quantized_demotions"] == 0
    assert "kv_quant" not in loss.metrics()   # quant keys stay opt-in too


def test_int8_promotion_within_divergence_budget(model):
    """Quantized demotion is lossy by design; the gate is a *measured*
    token-quality budget, not bit-identity: across re-referenced
    requests, mean leading-token agreement with the lossless engine
    stays >= 0.5 (observed ~0.9 at this scale), while the transcode
    path is demonstrably exercised."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    blk = _block_bytes(cfg, params)
    cap, host_cap = blk * 8, blk * 10

    def run(kv_quant):
        eng = _engine(cfg, params,
                      TieredKVStore(cap, "lerc", block_tokens=BT,
                                    host_capacity_bytes=host_cap,
                                    kv_quant=kv_quant))
        return eng, _serve(eng, reqs)

    lossless, lreqs = run(None)
    quantized, qreqs = run("int8")
    m = quantized.metrics()
    assert m["quantized_demotions"] > 0, "nothing was transcoded"
    assert m["dequantized_promotions"] > 0, "no quantized chain promoted"
    assert m["host_compression_ratio"] > 1.5

    def agree(a, b):
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n / max(len(a), 1)

    scores = [agree(q.generated, l.generated)
              for q, l in zip(qreqs, lreqs)]
    assert sum(scores) / len(scores) >= 0.5, scores


def test_disk_tier_promotion_is_lossless_and_disk_evicts(model):
    """Blocks that fell two rungs (device -> host -> memmap file) promote
    straight back to the device pool and generate exactly the big-cache
    tokens; an undersized disk rung exercises the third eviction index
    (disk_evictions + skeleton GC) without breaking the engine."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    blk = _block_bytes(cfg, params)

    big = _engine(cfg, params,
                  PrefixStore(1 << 30, "lerc", block_tokens=BT))
    breqs = _serve(big, reqs)

    disk = _engine(cfg, params,
                   TieredKVStore(blk * 8, "lerc", block_tokens=BT,
                                 host_capacity_bytes=blk * 3,
                                 disk_capacity_bytes=blk * 64))
    dreqs = _serve(disk, reqs)
    m = disk.metrics()
    assert m["disk_demotions"] > 0, "host pressure never reached disk"
    assert m["disk_promotions"] > 0, "no chain came back from disk"
    assert m["tier2_hits"] > 0
    assert [r.generated for r in dreqs] == [r.generated for r in breqs]

    tiny = _engine(cfg, params,
                   TieredKVStore(blk * 8, "lerc", block_tokens=BT,
                                 host_capacity_bytes=blk * 3,
                                 disk_capacity_bytes=blk * 4))
    _serve(tiny, reqs)
    assert tiny.metrics()["disk_evictions"] > 0, \
        "undersized disk rung produced no final evictions"
    assert len(tiny.store.disk_eviction_log) == \
        tiny.metrics()["disk_evictions"]

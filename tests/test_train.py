"""Optimizer math, grad accumulation, compression, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import make_dummy_batch
from repro.sharding import local_context
from repro.train import (AsyncCheckpointer, OptConfig, TrainConfig,
                         adamw_init, adamw_update, build_train_step,
                         compress_grads, ef_init, gc_old, latest, load,
                         make_train_state, save, schedule_lr)


def test_adamw_matches_reference_step():
    """One AdamW step vs a hand-written numpy reference (no decay/clip
    interference: wd=0, huge clip)."""
    oc = OptConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                   weight_decay=0.0, clip_norm=1e9, warmup_steps=0,
                   schedule="constant")
    p = {"w": jnp.array([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.array([0.1, 0.2, -0.3], jnp.float32)}
    st = adamw_init(p)
    new_p, st2, _ = adamw_update(oc, p, g, st)

    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2
    mh, vh = m / (1 - 0.9), v / (1 - 0.99)
    ref = np.array([1.0, -2.0, 3.0]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_schedule_warmup_and_cosine():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                   schedule="cosine", min_lr_frac=0.1)
    assert float(schedule_lr(oc, jnp.array(0))) == 0.0
    assert float(schedule_lr(oc, jnp.array(10))) == pytest.approx(1.0)
    assert float(schedule_lr(oc, jnp.array(110))) == pytest.approx(0.1)
    mid = float(schedule_lr(oc, jnp.array(60)))
    assert 0.1 < mid < 1.0


@pytest.mark.slow
def test_grad_accumulation_equivalent():
    """microbatches=2 must equal microbatches=1 on the same global batch."""
    cfg = configs.get("qwen2_7b", smoke=True).replace(dtype=jnp.float32)
    batch = make_dummy_batch(cfg, 4, 16)
    outs = {}
    for k in (1, 2):
        tc = TrainConfig(opt=OptConfig(warmup_steps=0, schedule="constant"),
                         microbatches=k)
        state = make_train_state(cfg, tc, jax.random.key(0))
        step = jax.jit(build_train_step(cfg, tc, local_context()))
        new_state, m = step(state, batch)
        outs[k] = (float(m["loss"]),
                   jax.tree.leaves(new_state["params"])[0])
    assert outs[1][0] == pytest.approx(outs[2][0], rel=1e-5)
    np.testing.assert_allclose(np.asarray(outs[1][1]),
                               np.asarray(outs[2][1]), atol=1e-5)


def test_compression_error_feedback_unbiased():
    """Error feedback: the cumulative transmitted gradient converges to the
    cumulative true gradient (bias is carried, not lost)."""
    g_true = {"w": jnp.array(np.random.default_rng(0)
                             .normal(size=512).astype(np.float32))}
    ef = ef_init(g_true)
    sent = jnp.zeros(512)
    for step in range(50):
        wire, ef = compress_grads(g_true, ef)
        sent = sent + wire["w"]
    total_true = g_true["w"] * 50
    # relative deviation of the sums shrinks to quantizer resolution
    rel = float(jnp.linalg.norm(sent - total_true)
                / jnp.linalg.norm(total_true))
    assert rel < 0.01, rel


def test_compression_single_step_is_quantized():
    g = {"w": jnp.linspace(-1, 1, 256)}
    wire, ef = compress_grads(g, ef_init(g))
    # int8 grid: at most 255 distinct values
    assert len(np.unique(np.asarray(wire["w"]))) <= 255
    np.testing.assert_allclose(np.asarray(wire["w"] + ef["w"]),
                               np.asarray(g["w"]), atol=1e-6)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tiny_state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                       "b": jnp.ones((4,), jnp.float32)},
            "opt": {"step": jnp.array(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    state = _tiny_state()
    path = save(str(tmp_path), 7, state)
    step, restored = load(path)
    assert step == 7
    assert restored["params"]["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"],
                                             np.float32),
                                  np.asarray(state["params"]["w"],
                                             np.float32))
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_atomicity(tmp_path):
    """A directory without a manifest (crash mid-write) is never loadable
    as 'latest'."""
    save(str(tmp_path), 1, _tiny_state())
    os.makedirs(tmp_path / "step_00000002.tmp-999")  # orphaned tmp
    os.makedirs(tmp_path / "step_00000003")          # no manifest: corrupt
    found = latest(str(tmp_path))
    assert found is not None and found.endswith("step_00000001")


def test_checkpoint_gc(tmp_path):
    for s in range(5):
        save(str(tmp_path), s, _tiny_state())
    gc_old(str(tmp_path), keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, _tiny_state())
    ck.wait()
    assert latest(str(tmp_path)).endswith("step_00000003")


@pytest.mark.slow
def test_resume_bitwise_identical(tmp_path):
    """Train 6 steps; checkpoint at 3; resume and re-run 3..6: the final
    parameters must match the uninterrupted run bitwise."""
    cfg = configs.get("qwen2_7b", smoke=True)
    tc = TrainConfig(opt=OptConfig(warmup_steps=0, schedule="constant"))
    from repro.data import LoaderConfig, TrainLoader
    lc = LoaderConfig(global_batch=4, seq_len=16, vocab=cfg.vocab, seed=3)

    def run(start_step, state, n):
        loader = TrainLoader(lc)
        step_fn = jax.jit(build_train_step(cfg, tc, local_context()))
        for s in range(start_step, start_step + n):
            state, _ = step_fn(state, loader.build_batch(s))
        return state

    s0 = make_train_state(cfg, tc, jax.random.key(0))
    full = run(0, s0, 6)

    s0 = make_train_state(cfg, tc, jax.random.key(0))
    half = run(0, s0, 3)
    save(str(tmp_path), 3, half)
    _, restored = load(latest(str(tmp_path)))
    resumed = run(3, restored, 3)

    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

"""End-to-end equivalence: the pooled/chunked engine vs the frozen legacy
engine, the pooled store vs the brute-force reference store, and the
zero-copy paged data plane vs the gather data plane.

The PR 2 data plane changed *representation* (device pool indices instead
of host arrays; chunked instead of token-at-a-time prefill) but must not
change *semantics*: on a shared-prefix workload with uniform prompt and
generation lengths (so the store-op interleaving is chunk-invariant),
every ``prefill_chunk`` must produce token-identical generations and a
bit-identical eviction log — and the pooled ``PrefixStore`` must agree
with ``ReferencePrefixStore`` op-for-op while the engine drives it.

PR 5 changes representation again (block tables + in-pool decode instead
of gather/scatter + per-slot contiguous caches) with the same obligation,
and because both planes share one engine control flow, the paged engine
must match the gather engine *at every* prefill_chunk, policy, tier
configuration, and shard count — token-identical generations with
bit-identical eviction logs and ERC counters.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params, model_spec
from repro.models.common import ModelConfig
from repro.serve import (LegacyServeEngine, PrefixStore,
                         ReferencePrefixStore, ServeEngine, ShardedFrontend,
                         TieredKVStore)
from repro.sharding import serve_tp_context

BT = 8          # block_tokens
PROMPT = 32     # uniform prompt length (4 blocks)
MAX_NEW = 4


class ShadowStore:
    """Forwards every store op to the pooled incremental store AND the
    brute-force reference, asserting bit-identical behavior after each op.
    The reference never sees payloads (it stays payload-agnostic)."""

    def __init__(self, inc: PrefixStore, ref: ReferencePrefixStore):
        self.inc, self.ref = inc, ref
        self.block_tokens = inc.block_tokens
        self.capacity = inc.capacity

    # engine wires the pool's index reclaim through this attribute
    @property
    def evict_payload(self):
        return self.inc.evict_payload

    @evict_payload.setter
    def evict_payload(self, fn):
        self.inc.evict_payload = fn

    def _check(self):
        assert self.inc.eviction_log == self.ref.eviction_log

    def register_request(self, tokens):
        rid = self.inc.register_request(tokens)
        assert rid == self.ref.register_request(tokens)
        self._check()
        return rid

    def lookup(self, tokens):
        a = self.inc.lookup(tokens)
        b = self.ref.lookup(tokens)
        assert [n.uid for n in a] == [n.uid for n in b]
        self._check()
        return a

    def insert(self, tokens, payloads, nbytes_per_block):
        self.inc.insert(tokens, payloads, nbytes_per_block)
        self.ref.insert(tokens, lambda i, n: None, nbytes_per_block)
        self._check()
        # ERC counters: incremental vs from-scratch recomputation
        rc, erc = self.ref._ref_counts()
        for bid in self.inc._nodes:
            assert self.inc.state.ref_count.get(bid, 0) == rc.get(bid, 0)
            assert self.inc.state.eff_ref_count.get(bid, 0) == \
                erc.get(bid, 0)

    def complete_request(self, rid):
        self.inc.complete_request(rid)
        self.ref.complete_request(rid)
        self._check()

    def metrics(self):
        m = self.inc.metrics()
        assert m == self.ref.metrics()
        return m


@pytest.fixture(scope="module")
def model():
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    return cfg, params


def workload(vocab, n_requests=8, n_families=3, seed=7):
    """Shared-prefix requests with uniform lengths."""
    rng = np.random.default_rng(seed)
    prefixes = [list(rng.integers(0, vocab, PROMPT - BT))
                for _ in range(n_families)]
    return [prefixes[i % n_families]
            + list(rng.integers(0, vocab, BT)) for i in range(n_requests)]


def capacity(cfg, params):
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=PrefixStore(1 << 30, "lerc", block_tokens=BT),
                        pool_blocks=1)
    return probe._block_nbytes() * 10           # < working set -> evictions


def test_pooled_chunked_engine_matches_legacy(model):
    """Single slot: the store-op stream is strictly sequential (lookup →
    insert → complete per request), so it is *provably* chunk-invariant —
    generations AND eviction logs must be bit-identical across
    prefill_chunk and vs the legacy engine."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    cap = capacity(cfg, params)

    legacy = LegacyServeEngine(
        cfg, params, max_slots=1, max_seq=64,
        store=PrefixStore(cap, "lerc", block_tokens=BT))
    lreqs = [legacy.submit(r, max_new=MAX_NEW) for r in reqs]
    legacy.run()
    assert legacy.store.evictions > 0, "workload produced no pressure"

    for chunk in (1, 4, 8):
        inc = PrefixStore(cap, "lerc", block_tokens=BT)
        ref = ReferencePrefixStore(cap, "lerc", block_tokens=BT)
        eng = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                          store=ShadowStore(inc, ref),
                          prefill_chunk=chunk)
        ereqs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
        eng.run()

        # token-identical generations vs the legacy hot path
        assert [r.generated for r in ereqs] == \
            [r.generated for r in lreqs], f"prefill_chunk={chunk}"
        # identical eviction decisions vs the legacy engine's store...
        assert inc.eviction_log == legacy.store.eviction_log, \
            f"prefill_chunk={chunk}"
        # ...and (asserted op-by-op above) vs the brute-force reference
        assert inc.eviction_log == ref.eviction_log
        # identical prefix reuse
        assert [r.prefill_skipped for r in ereqs] == \
            [r.prefill_skipped for r in lreqs]

        # the hit/insert path never leaves the device: payloads are pool
        # indices, not host arrays
        for node in inc._nodes.values():
            if node.resident:
                assert isinstance(node.payload, int)

        # chunked prefill does the same token work in ~P/chunk dispatches
        assert eng.prefill_tokens == legacy.prefill_tokens
        if chunk > 1:
            assert eng.steps < legacy.steps


def test_continuous_batching_matches_legacy(model):
    """Multi-slot. At chunk=1 the engines are dispatch-for-dispatch
    identical, so the full store trace must match. At chunk>1 the *timing*
    of store ops across slots shifts (cold and warm prefills shrink by
    different factors), so eviction decisions may legitimately differ —
    but generations are KV-exact and must stay token-identical."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    cap = capacity(cfg, params)

    legacy = LegacyServeEngine(
        cfg, params, max_slots=2, max_seq=64,
        store=PrefixStore(cap, "lerc", block_tokens=BT))
    lreqs = [legacy.submit(r, max_new=MAX_NEW) for r in reqs]
    legacy.run()

    for chunk in (1, 8):
        st = PrefixStore(cap, "lerc", block_tokens=BT)
        eng = ServeEngine(cfg, params, max_slots=2, max_seq=64, store=st,
                          prefill_chunk=chunk)
        ereqs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
        eng.run()
        assert [r.generated for r in ereqs] == \
            [r.generated for r in lreqs], f"prefill_chunk={chunk}"
        if chunk == 1:
            assert st.eviction_log == legacy.store.eviction_log
            assert [r.prefill_skipped for r in ereqs] == \
                [r.prefill_skipped for r in lreqs]
            assert eng.steps == legacy.steps


def test_prefill_step_count_scales_with_chunk(model):
    """A P-token cold prompt must prefill in ceil(P/chunk) steps (>=4x
    fewer at chunk=8 for P=32), not ~P."""
    cfg, params = model
    rng = np.random.default_rng(3)
    prompt = list(rng.integers(0, cfg.vocab, PROMPT))
    steps = {}
    for chunk in (1, 8):
        eng = ServeEngine(cfg, params, max_slots=1, max_seq=64,
                          store=PrefixStore(1 << 30, "lerc",
                                            block_tokens=BT),
                          prefill_chunk=chunk)
        eng.submit(prompt, max_new=MAX_NEW)
        eng.run()
        # the final prefill dispatch also emits the first generated token,
        # so decode adds MAX_NEW - 1 further dispatches
        steps[chunk] = eng.steps - (MAX_NEW - 1)
    assert steps[1] == PROMPT
    assert steps[8] == -(-PROMPT // 8)
    assert steps[1] >= 4 * steps[8]


def _run_engine(cfg, params, reqs, *, store, chunk, paged, slots=2):
    eng = ServeEngine(cfg, params, max_slots=slots, max_seq=64, store=store,
                      prefill_chunk=chunk, paged=paged)
    rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
    eng.run()
    return eng, rs


@pytest.mark.parametrize("policy", ["lru", "lrc", "lerc"])
@pytest.mark.parametrize("chunk", [1, 8])
def test_paged_engine_matches_gather(model, policy, chunk):
    """The zero-copy paged plane vs the gather plane, same policy and
    chunk: token-identical generations, bit-identical eviction logs AND
    incremental ERC counters, identical prefix reuse — and the paged arm
    must not have issued a single chain-copy dispatch beyond copy-on-write
    (the workload ends with a duplicate prompt, so the fully-resident-hit
    CoW path is exercised too)."""
    cfg, params = model
    reqs = workload(cfg.vocab)
    reqs.append(list(reqs[0]))      # full-chain hit -> copy-on-write
    cap = capacity(cfg, params)

    gst = PrefixStore(cap, policy, block_tokens=BT)
    geng, greqs = _run_engine(cfg, params, reqs, store=gst, chunk=chunk,
                              paged=False)
    pst = PrefixStore(cap, policy, block_tokens=BT)
    peng, preqs = _run_engine(cfg, params, reqs, store=pst, chunk=chunk,
                              paged=True)

    assert [r.generated for r in preqs] == [r.generated for r in greqs]
    assert pst.eviction_log == gst.eviction_log
    assert [r.prefill_skipped for r in preqs] == \
        [r.prefill_skipped for r in greqs]
    assert pst.state.ref_count == gst.state.ref_count
    assert pst.state.eff_ref_count == gst.state.eff_ref_count
    assert pst.metrics() == gst.metrics()
    assert peng.steps == geng.steps
    # a hit is a host-side block-table write: the only transfer dispatches
    # the paged plane ever issues are one-row copy-on-write copies
    assert peng.transfer_dispatches <= 1
    assert geng.transfer_dispatches > 0
    # every pool row is reclaimed once the store and the slots let go
    assert peng.pool.blocks_in_use == \
        sum(1 for n in pst._nodes.values() if n.resident) + 1  # junk row


def test_paged_tiered_promotion_into_block_tables(model):
    """TieredKVStore under the paged plane: demoted chains promote back
    into pool rows that prefix hits then reference via block tables —
    token-identical to the gather plane with the same tier config, same
    eviction/demotion/promotion stream."""
    cfg, params = model
    reqs = workload(cfg.vocab, n_requests=10, n_families=2, seed=3)
    blk = capacity(cfg, params) // 10
    results = {}
    for paged in (False, True):
        st = TieredKVStore(blk * 6, "lerc", block_tokens=BT,
                           host_capacity_bytes=blk * 64)
        eng, rs = _run_engine(cfg, params, reqs, store=st, chunk=8,
                              paged=paged)
        results[paged] = (rs, st)
    (grs, gst), (prs, pst) = results[False], results[True]
    assert pst.metrics_obj.promotions > 0, "workload exercised no promotion"
    assert [r.generated for r in prs] == [r.generated for r in grs]
    assert pst.eviction_log == gst.eviction_log
    assert pst.host_eviction_log == gst.host_eviction_log
    assert pst.metrics_obj.demotions == gst.metrics_obj.demotions
    assert pst.metrics_obj.promotions == gst.metrics_obj.promotions


def test_paged_sharded_matches_gather_sharded(model):
    """2-shard frontend, paged vs gather shards: token-identical, same
    per-shard eviction logs, replicas coherent."""
    cfg, params = model
    reqs = workload(cfg.vocab, n_requests=10, seed=5)
    cap = capacity(cfg, params)
    results = {}
    for paged in (False, True):
        fe = ShardedFrontend(cfg, params, 2, max_slots=2, max_seq=64,
                             capacity_bytes=cap, policy="lerc",
                             block_tokens=BT, prefill_chunk=8, paged=paged)
        rs = [fe.submit(r, max_new=MAX_NEW)[1] for r in reqs]
        fe.run()
        fe.verify_replicas()
        results[paged] = (rs, fe)
    (grs, gfe), (prs, pfe) = results[False], results[True]
    assert [r.generated for r in prs] == [r.generated for r in grs]
    for ge, pe in zip(gfe.shards, pfe.shards):
        assert pe.store.eviction_log == ge.store.eviction_log
        assert pe.paged and not ge.paged


def test_scheduled_fcfs_matches_run_loop(model):
    """PR 6 front door, zero-delta proof: an engine driven by the trace
    event loop under an explicit FCFS scheduler must be *bit-identical* —
    token-for-token generations AND the same eviction log — to the plain
    submit-then-``run()`` loop. (All arrivals at t=0 makes the admission
    order equal to submission order, so every step dispatches the same
    work; the scheduler layer adds latency accounting, never behavior.)"""
    from repro.serve import FCFSScheduler, TracedRequest, play_trace

    cfg, params = model
    reqs = workload(cfg.vocab)
    cap = capacity(cfg, params)

    plain_st = PrefixStore(cap, "lerc", block_tokens=BT)
    plain = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=plain_st, prefill_chunk=8, paged=True)
    preqs = [plain.submit(r, max_new=MAX_NEW) for r in reqs]
    plain.run()
    assert plain_st.evictions > 0, "workload produced no pressure"

    sched_st = PrefixStore(cap, "lerc", block_tokens=BT)
    sched = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=sched_st, prefill_chunk=8, paged=True,
                        scheduler=FCFSScheduler())
    trace = [TracedRequest(t=0.0, prompt=r, max_new=MAX_NEW) for r in reqs]
    report = play_trace(sched, trace)

    assert [r.generated for r in report.requests] == \
        [r.generated for r in preqs]
    assert sched_st.eviction_log == plain_st.eviction_log
    assert [r.prefill_skipped for r in report.requests] == \
        [r.prefill_skipped for r in preqs]
    assert sched.steps == plain.steps


# ---------------------------------------------------------------------------
# Tensor-parallel paged serving (PR 7)
# ---------------------------------------------------------------------------
# The default smoke config has 1 KV head (unshardable), so TP runs on a
# dedicated config whose 4 KV heads divide every mesh under test. The
# engines below must be *token-identical with bit-identical eviction logs*
# across meshless / 1-device mesh / tp=2 / tp=4: the attention outputs are
# all-gathered inside the shard_map, so the output projection (and hence
# every logit) is computed in single-device summation order on every tp.

TP_CFG = ModelConfig(arch="tp_smoke", family="dense", n_layers=2,
                     d_model=32, n_heads=8, n_kv_heads=4, d_head=8,
                     d_ff=64, vocab=256, act="swiglu", layer_pattern="G")


@pytest.fixture(scope="module")
def tp_model():
    params = init_params(jax.random.key(0), model_spec(TP_CFG),
                         dtype=TP_CFG.dtype)
    return TP_CFG, params


def _tp_store(tiered, policy, blk):
    if tiered:
        return TieredKVStore(blk * 6, policy, block_tokens=BT,
                             host_capacity_bytes=blk * 64)
    return PrefixStore(blk * 10, policy, block_tokens=BT)


def _run_tp(cfg, params, reqs, *, policy, tiered, tp):
    probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                        store=PrefixStore(1 << 30, policy, block_tokens=BT),
                        pool_blocks=1, paged=True)
    blk = probe._block_nbytes()
    st = _tp_store(tiered, policy, blk)
    kw = {"kv_shard": serve_tp_context(tp)} if tp else {}
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64, store=st,
                      prefill_chunk=8, paged=True, **kw)
    rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
    eng.run()
    return eng, rs, st


def test_mesh1_engine_bit_identical(tp_model):
    """An engine built on a 1-device mesh (shard_map, NamedSharding-
    committed pool, replicated params) is bit-identical to the meshless
    engine: same tokens, same eviction log, same ERC counters. Runs in
    the plain 1-device tier-1 suite."""
    cfg, params = tp_model
    reqs = workload(cfg.vocab)
    base, brs, bst = _run_tp(cfg, params, reqs, policy="lerc",
                             tiered=False, tp=0)
    mesh, mrs, mst = _run_tp(cfg, params, reqs, policy="lerc",
                             tiered=False, tp=1)
    assert bst.evictions > 0, "workload produced no pressure"
    assert [r.generated for r in mrs] == [r.generated for r in brs]
    assert mst.eviction_log == bst.eviction_log
    assert mst.state.ref_count == bst.state.ref_count
    assert mst.state.eff_ref_count == bst.state.eff_ref_count
    assert [r.prefill_skipped for r in mrs] == \
        [r.prefill_skipped for r in brs]
    # the per-device/global byte split collapses at tp=1
    assert mesh.tp == 1
    assert mesh.pool.nbytes_per_device == mesh.pool.nbytes


@pytest.mark.parametrize("tiered", [False, True],
                         ids=["paged", "tiered"])
@pytest.mark.parametrize("policy", ["lru", "lerc"])
def test_tp_engines_token_identical(tp_model, policy, tiered):
    """tp ∈ {1, 2, 4} engines vs the meshless engine: token-identical
    generations, bit-identical eviction logs (and demotion/promotion
    streams on the tiered store). Needs forced host devices — the CI TP
    leg runs with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    cfg, params = tp_model
    reqs = workload(cfg.vocab, n_requests=10, n_families=2, seed=3)
    base, brs, bst = _run_tp(cfg, params, reqs, policy=policy,
                             tiered=tiered, tp=0)
    if tiered:
        assert bst.metrics_obj.promotions > 0, "no promotion exercised"
    else:
        assert bst.evictions > 0, "workload produced no pressure"
    tps = [1, 2] + ([4] if jax.device_count() >= 4 else [])
    for tp in tps:
        eng, rs, st = _run_tp(cfg, params, reqs, policy=policy,
                              tiered=tiered, tp=tp)
        assert [r.generated for r in rs] == \
            [r.generated for r in brs], f"tp={tp}"
        assert st.eviction_log == bst.eviction_log, f"tp={tp}"
        if tiered:
            assert st.host_eviction_log == bst.host_eviction_log
            assert st.metrics_obj.demotions == bst.metrics_obj.demotions
            assert st.metrics_obj.promotions == bst.metrics_obj.promotions
        # satellite: per-device vs global bytes reported explicitly
        assert eng.pool.nbytes_per_device * tp == eng.pool.nbytes
        m = eng.metrics()
        assert m["serve_tp"] == tp
        assert m["device_kv_bytes"] * tp == m["kv_bytes_global"]


def test_tp_disk_quant_promotion_token_identical(tp_model):
    """PR 8 under TP: an int8 host tier plus a disk rung behaves
    identically across mesh widths. The quantize amax reduction over the
    sharded KV axis is an exact max all-reduce, so every replica computes
    the same scales — tp=2 must generate token-for-token what tp=1 does,
    with bit-identical eviction/demotion streams and disk traffic."""
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    cfg, params = tp_model
    reqs = workload(cfg.vocab, n_requests=12, n_families=3, seed=5)

    def run(tp):
        probe = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                            store=PrefixStore(1 << 30, "lerc",
                                              block_tokens=BT),
                            pool_blocks=1, paged=True)
        blk = probe._block_nbytes()
        st = TieredKVStore(blk * 6, "lerc", block_tokens=BT,
                           host_capacity_bytes=blk * 2,
                           kv_quant="int8",
                           disk_capacity_bytes=blk * 64)
        eng = ServeEngine(cfg, params, max_slots=2, max_seq=64, store=st,
                          prefill_chunk=8, paged=True,
                          kv_shard=serve_tp_context(tp))
        rs = [eng.submit(r, max_new=MAX_NEW) for r in reqs]
        eng.run()
        return eng, rs, st

    e1, r1, s1 = run(1)
    m1 = e1.metrics()
    assert m1["quantized_demotions"] > 0, "nothing was transcoded"
    assert m1["disk_promotions"] > 0, "no chain came back from disk"
    e2, r2, s2 = run(2)
    assert [r.generated for r in r2] == [r.generated for r in r1]
    assert s2.eviction_log == s1.eviction_log
    assert s2.host_eviction_log == s1.host_eviction_log
    assert s2.disk_eviction_log == s1.disk_eviction_log
    m2 = e2.metrics()
    for k in ("demotions", "promotions", "disk_demotions",
              "disk_promotions", "quantized_demotions",
              "dequantized_promotions", "tier2_hits"):
        assert m2[k] == m1[k], k


def test_tp_rejects_gather_plane_and_indivisible_heads(tp_model):
    """TP is paged-plane only and must refuse KV-head counts the mesh
    cannot split — loud errors, not silent wrong sharding."""
    cfg, params = tp_model
    ctx = serve_tp_context(1)
    with pytest.raises(ValueError, match="gather"):
        ServeEngine(cfg, params, max_slots=2, max_seq=64,
                    paged=False, kv_shard=ctx)
    bad = configs.get("qwen2_7b", smoke=True)     # 1 KV head
    bad_params = init_params(jax.random.key(0), model_spec(bad),
                             dtype=bad.dtype)
    if jax.device_count() >= 2:
        with pytest.raises(ValueError, match="kv_heads"):
            ServeEngine(bad, bad_params, max_slots=2, max_seq=64,
                        paged=True, tp=2)


def test_pool_reclaims_evicted_blocks(model):
    """Evictions free pool rows O(1); sustained traffic must not grow the
    pool past the byte budget's block count."""
    cfg, params = model
    cap = capacity(cfg, params)
    st = PrefixStore(cap, "lerc", block_tokens=BT)
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64, store=st)
    n_budget = cap // eng._block_nbytes()
    for r in workload(cfg.vocab, n_requests=12, seed=11):
        eng.submit(r, max_new=MAX_NEW)
    eng.run()
    assert st.evictions > 0
    assert eng.pool.grows == 0
    assert eng.pool.blocks_in_use <= n_budget
    assert eng.pool.num_blocks <= n_budget + 1

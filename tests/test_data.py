"""Data pipeline (LERC block cache, disk spill) and loader tests."""
import numpy as np
import pytest

from repro.data import (Executor, LoaderConfig, Pipeline,
                        SyntheticTokenSource, TrainLoader)


def _zip_pipeline(n_blocks=8, block=512):
    rng = np.random.default_rng(0)
    A = [rng.integers(0, 100, block).astype(np.int32)
         for _ in range(n_blocks)]
    B = [rng.integers(0, 100, block).astype(np.int32)
         for _ in range(n_blocks)]
    pipe = Pipeline("t")
    ra = pipe.source(A, "A")
    rb = pipe.source(B, "B")
    rz = pipe.zip_([ra, rb], lambda a, b: a + b, "Z")
    return pipe, ra, rb, rz, A, B


def test_pipeline_correctness_under_pressure(tmp_path):
    pipe, ra, rb, rz, A, B = _zip_pipeline()
    nbytes = A[0].nbytes
    ex = Executor(pipe, cache_bytes=5 * nbytes, policy="lerc",
                  spill_dir=str(tmp_path))
    ex.load_sources(ra)
    ex.load_sources(rb)
    outs = ex.materialize(rz)
    for i in range(8):
        np.testing.assert_array_equal(outs[i], A[i] + B[i])
    assert ex.stats.disk_writes > 0          # pressure forced spills
    assert ex.metrics.evictions > 0


@pytest.mark.parametrize("policy", ["lru", "lrc", "lerc"])
def test_pipeline_all_policies_correct(tmp_path, policy):
    """Eviction policy must never affect RESULTS, only performance."""
    pipe, ra, rb, rz, A, B = _zip_pipeline(n_blocks=6)
    ex = Executor(pipe, cache_bytes=4 * A[0].nbytes, policy=policy,
                  spill_dir=str(tmp_path))
    ex.load_sources(ra)
    ex.load_sources(rb)
    outs = ex.materialize(rz)
    for i in range(6):
        np.testing.assert_array_equal(outs[i], A[i] + B[i])


def test_lerc_beats_lru_on_effective_hits(tmp_path):
    """The paper's claim on the real pipeline: same workload, same cache
    budget — LERC keeps peer pairs together and gets more effective hits
    than LRU (which interleaves A/B evictions)."""
    results = {}
    for policy in ("lru", "lerc"):
        pipe, ra, rb, rz, A, B = _zip_pipeline(n_blocks=10)
        ex = Executor(pipe, cache_bytes=10 * A[0].nbytes, policy=policy,
                      spill_dir=str(tmp_path / policy))
        ex.load_sources(ra)
        ex.load_sources(rb)
        ex.materialize(rz)
        results[policy] = ex.metrics.effective_hit_ratio
    assert results["lerc"] >= results["lru"]
    assert results["lerc"] > 0


def test_map_and_coalesce(tmp_path):
    rng = np.random.default_rng(1)
    X = [rng.normal(size=64).astype(np.float32) for _ in range(8)]
    pipe = Pipeline("m")
    rx = pipe.source(X, "X")
    r2 = pipe.map(rx, lambda a: a * 2, "D")
    rc = pipe.coalesce(r2, 4, name="C")
    ex = Executor(pipe, cache_bytes=1 << 20, spill_dir=str(tmp_path))
    ex.load_sources(rx)
    outs = ex.materialize(rc)
    np.testing.assert_allclose(outs[0], np.concatenate([x * 2
                                                        for x in X[:4]]))
    assert len(outs) == 2


# ---------------------------------------------------------------------------
# loader
# ---------------------------------------------------------------------------


def test_loader_host_sharding_disjoint():
    lc0 = LoaderConfig(global_batch=8, seq_len=32, vocab=100, n_hosts=2,
                       host_id=0)
    lc1 = LoaderConfig(global_batch=8, seq_len=32, vocab=100, n_hosts=2,
                       host_id=1)
    b0 = TrainLoader(lc0).build_batch(0)
    b1 = TrainLoader(lc1).build_batch(0)
    assert not (b0["tokens"] == b1["tokens"]).all()
    assert b0["tokens"].shape == (4, 32)


def test_loader_targets_shifted():
    lc = LoaderConfig(global_batch=2, seq_len=16, vocab=50)
    b = TrainLoader(lc).build_batch(0)
    src = SyntheticTokenSource(50, 17, 0)
    row0 = src.block(0)
    np.testing.assert_array_equal(b["tokens"][0], row0[:-1])
    np.testing.assert_array_equal(b["targets"][0], row0[1:])


def test_loader_resume_replays_exactly():
    lc = LoaderConfig(global_batch=4, seq_len=16, vocab=100, seed=9)
    l1 = TrainLoader(lc)
    batches = [l1.build_batch(s) for s in range(4)]
    l2 = TrainLoader(lc)
    l2.load_state_dict({"next_step": 2})
    again = l2.build_batch(2)
    np.testing.assert_array_equal(batches[2]["tokens"], again["tokens"])


def test_loader_straggler_work_stealing():
    """A slow fetch for one row must not corrupt or reorder the batch."""
    import time
    lc = LoaderConfig(global_batch=6, seq_len=8, vocab=100, n_workers=3)

    def slow_fetch(step, slot):
        if slot == 2:
            time.sleep(0.05)          # straggler
        rng = np.random.default_rng((step, slot))
        return rng.integers(0, 100, 9, dtype=np.int32)

    loader = TrainLoader(lc, fetch_block=slow_fetch)
    batch = loader.build_batch(0)
    for s in range(6):
        rng = np.random.default_rng((0, s))
        np.testing.assert_array_equal(
            batch["tokens"][s], rng.integers(0, 100, 9, dtype=np.int32)[:-1])

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.kernels import flash_attention, rglru_scan, rwkv6_wkv
from repro.kernels.ref import attention_ref, rglru_ref, rwkv6_ref


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, S, H, KV, D, causal, window, softcap, dtype, tol)
    (1, 128, 2, 2, 64, True, None, None, jnp.float32, 2e-5),
    (2, 256, 4, 1, 64, True, None, None, jnp.float32, 2e-5),   # MQA
    (1, 256, 8, 2, 64, True, None, 50.0, jnp.float32, 2e-5),   # softcap
    (1, 320, 4, 4, 64, True, 128, None, jnp.float32, 2e-5),    # window
    (2, 192, 2, 2, 128, False, None, None, jnp.float32, 2e-5), # bidi
    (1, 256, 4, 2, 64, True, None, None, jnp.bfloat16, 2e-2),  # bf16
    (1, 100, 2, 1, 64, True, 32, 30.0, jnp.float32, 2e-5),     # ragged+all
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=[str(c[:5]) + f"c{c[5]}w{c[6]}s{c[7]}"
                              for c in FLASH_CASES])
def test_flash_attention_matches_oracle(case):
    B, S, H, KV, D, causal, window, softcap, dtype, tol = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case[:5]) % 2**31), 3)
    q = _rand(ks[0], (B, S, H, D), dtype)
    k = _rand(ks[1], (B, S, KV, D), dtype)
    v = _rand(ks[2], (B, S, KV, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=64, block_k=64)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=25, deadline=None)
@given(B=st.integers(1, 2), S=st.integers(16, 200),
       H=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]),
       D=st.sampled_from([32, 64]), causal=st.booleans())
def test_flash_attention_hypothesis(B, S, H, g, D, causal):
    KV = max(H // g, 1)
    H = KV * g
    ks = jax.random.split(jax.random.PRNGKey(S * 7 + H), 3)
    q = _rand(ks[0], (B, S, H, D))
    k = _rand(ks[1], (B, S, KV, D))
    v = _rand(ks[2], (B, S, KV, D))
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

RGLRU_CASES = [(1, 64, 128, 16, 128), (2, 200, 256, 64, 128),
               (1, 256, 512, 256, 256), (3, 33, 128, 32, 128)]


@pytest.mark.parametrize("case", RGLRU_CASES)
def test_rglru_matches_oracle(case):
    B, T, W, bt, bw = case
    ks = jax.random.split(jax.random.PRNGKey(T + W), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W)))
    b = jax.random.normal(ks[1], (B, T, W))
    y, hl = rglru_scan(a, b, block_t=bt, block_w=bw)
    yr, hr = rglru_ref(a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hr),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 3), T=st.integers(4, 100),
       W=st.sampled_from([128, 256]), bt=st.sampled_from([16, 64]))
def test_rglru_hypothesis(B, T, W, bt):
    ks = jax.random.split(jax.random.PRNGKey(B * 1000 + T), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W)))
    b = jax.random.normal(ks[1], (B, T, W))
    y, _ = rglru_scan(a, b, block_t=bt, block_w=128)
    yr, _ = rglru_ref(a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

RWKV_CASES = [(1, 64, 2, 32, 16), (2, 96, 4, 64, 32), (1, 50, 2, 16, 32),
              (1, 128, 2, 128, 32)]


@pytest.mark.parametrize("case", RWKV_CASES)
def test_rwkv6_matches_oracle(case):
    B, T, H, N, C = case
    ks = jax.random.split(jax.random.PRNGKey(T + N), 5)
    r = _rand(ks[0], (B, T, H, N), scale=0.5)
    k = _rand(ks[1], (B, T, H, N), scale=0.5)
    v = _rand(ks[2], (B, T, H, N), scale=0.5)
    logw = jnp.clip(-jnp.exp(_rand(ks[3], (B, T, H, N), scale=0.5)),
                    -5.0, -1e-6)
    u = _rand(ks[4], (H, N), scale=0.5)
    out = rwkv6_wkv(r, k, v, logw, u, chunk=C)
    ref = rwkv6_ref(r, k, v, logw, u)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert float(jnp.max(jnp.abs(out - ref))) / scale < 1e-4


def test_rwkv6_chunk_invariance():
    """Different chunk sizes must give identical results (state handoff)."""
    B, T, H, N = 1, 96, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (_rand(ks[i], (B, T, H, N), scale=0.5) for i in range(3))
    logw = jnp.clip(-jnp.exp(_rand(ks[3], (B, T, H, N), scale=0.3)),
                    -5.0, -1e-6)
    u = _rand(ks[4], (H, N), scale=0.5)
    o16 = rwkv6_wkv(r, k, v, logw, u, chunk=16)
    o48 = rwkv6_wkv(r, k, v, logw, u, chunk=48)
    np.testing.assert_allclose(np.asarray(o16), np.asarray(o48),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# flash-decoding (split-K decode attention)
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 128, 4, 2, 64, None, None),
    (1, 200, 8, 1, 64, None, 50.0),      # MQA + softcap, ragged S
    (3, 256, 4, 4, 64, 64, None),        # sliding window
    (2, 96, 8, 2, 128, None, None),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_oracle(case):
    from repro.kernels import decode_attention
    B, S, H, KV, D, window, softcap = case
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = _rand(ks[0], (B, H, D))
    k = _rand(ks[1], (B, S, KV, D))
    v = _rand(ks[2], (B, S, KV, D))
    valid = jnp.array([S - 7 * i for i in range(B)], jnp.int32)
    out = decode_attention(q, k, v, valid, window=window, softcap=softcap,
                           block_k=64)
    for b in range(B):
        vl = int(valid[b])
        lo = max(0, vl - window) if window is not None else 0
        ref = attention_ref(q[b:b + 1, None], k[b:b + 1, lo:vl],
                            v[b:b + 1, lo:vl], causal=False,
                            softcap=softcap)
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(ref[0, 0]),
                                   atol=3e-5, rtol=3e-5)


def test_decode_attention_matches_model_decode_path():
    """The kernel must agree with the model's XLA decode attention on the
    same cache contents (integration-level oracle)."""
    from repro.kernels import decode_attention
    B, S, H, KV, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(ks[0], (B, 1, H, D))
    kc = _rand(ks[1], (B, S, KV, D))
    vc = _rand(ks[2], (B, S, KV, D))
    valid = jnp.array([S, S - 9], jnp.int32)
    out_kernel = decode_attention(q[:, 0], kc, vc, valid, block_k=32)
    ref = []
    for b in range(B):
        vl = int(valid[b])
        ref.append(attention_ref(q[b:b + 1], kc[b:b + 1, :vl],
                                 vc[b:b + 1, :vl], causal=False)[0, 0])
    np.testing.assert_allclose(np.asarray(out_kernel),
                               np.asarray(jnp.stack(ref)),
                               atol=3e-5, rtol=3e-5)

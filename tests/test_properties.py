"""Property-based tests (hypothesis) for the system's invariants."""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (BlockMeta, CacheManager, DagState, JobDAG, TaskSpec,
                        build_cluster, make_policy)


# ---------------------------------------------------------------------------
# Random DAG + event-sequence machinery
# ---------------------------------------------------------------------------


def random_dag(draw) -> JobDAG:
    dag = JobDAG()
    n_src = draw(st.integers(3, 8))
    for i in range(n_src):
        dag.add_source("s", i, size=draw(st.integers(1, 3)))
    n_tasks = draw(st.integers(1, 6))
    for t in range(n_tasks):
        k = draw(st.integers(1, min(3, n_src)))
        inputs = tuple(f"s[{i}]" for i in sorted(
            draw(st.sets(st.integers(0, n_src - 1), min_size=k, max_size=k))))
        out = f"o{t}"
        dag.add_block(BlockMeta(out, 1, "o", t))
        dag.add_task(TaskSpec(f"t{t}", inputs, out, job="j"))
    return dag


dag_strategy = st.builds(lambda d: d, st.just(None)).flatmap(
    lambda _: st.composite(lambda draw: random_dag(draw))())

event_strategy = st.lists(
    st.tuples(st.sampled_from(["insert", "evict", "load", "task_done"]),
              st.integers(0, 10)),
    min_size=0, max_size=30)


@settings(max_examples=200, deadline=None)
@given(dag=st.composite(lambda draw: random_dag(draw))(),
       events=event_strategy)
def test_incremental_counts_match_oracle(dag, events):
    """After ANY event sequence, incrementally-maintained ref counts and
    effective ref counts equal a from-scratch rebuild (the paper's
    Definitions computed directly)."""
    state = DagState(dag)
    mgr = CacheManager(capacity=4, policy=make_policy("lerc"), state=state)
    blocks = sorted(dag.blocks)
    tasks = sorted(dag.tasks)
    for kind, idx in events:
        if kind == "insert":
            b = blocks[idx % len(blocks)]
            if b not in mgr.mem and dag.blocks[b].size <= mgr.mem.capacity:
                mgr.insert(b, dag.blocks[b].size)
        elif kind == "evict":
            if mgr.mem.blocks:
                b = sorted(mgr.mem.blocks)[idx % len(mgr.mem.blocks)]
                if b not in mgr.pinned:
                    mgr.evict(b)
        elif kind == "load":
            spilled = sorted(set(mgr.disk.blocks) - set(mgr.mem.blocks))
            if spilled:
                mgr.load_from_disk(spilled[idx % len(spilled)])
        elif kind == "task_done":
            t = tasks[idx % len(tasks)]
            state.on_task_done(t)

    oracle = DagState(dag, materialized=set(state.materialized),
                      cached=set(state.cached),
                      done_tasks=set(state.done_tasks))
    assert state.ref_count == oracle.ref_count
    assert state.eff_ref_count == oracle.eff_ref_count


@settings(max_examples=100, deadline=None)
@given(dag=st.composite(lambda draw: random_dag(draw))())
def test_effective_refs_bounded_by_refs(dag):
    state = DagState(dag)
    mgr = CacheManager(capacity=3, policy=make_policy("lerc"), state=state)
    for b in sorted(dag.blocks)[:5]:
        if dag.blocks[b].size <= 3:
            mgr.insert(b, dag.blocks[b].size)
    for b in dag.blocks:
        assert 0 <= state.eff_ref_count.get(b, 0) <= state.ref_count.get(b, 0)


def random_jobs(draw):
    """Multi-job workload over a shared source pool: job j may read any
    block that exists when it arrives (sources or earlier jobs' outputs),
    so peer groups span job boundaries — the composed-DAG case the
    incremental peer-profile protocol must handle."""
    n_src = draw(st.integers(3, 6))
    sources = [BlockMeta(f"s[{i}]", draw(st.integers(1, 3)), "s", i)
               for i in range(n_src)]
    known = list(sources)
    jobs = []
    n_jobs = draw(st.integers(1, 3))
    for j in range(n_jobs):
        dag = JobDAG()
        in_dag = set()

        def need(block):
            if block.id not in in_dag:
                dag.add_block(block)
                in_dag.add(block.id)

        n_tasks = draw(st.integers(1, 4))
        new_outputs = []
        for t in range(n_tasks):
            k = draw(st.integers(1, min(3, len(known))))
            picks = draw(st.sets(st.integers(0, len(known) - 1),
                                 min_size=k, max_size=k))
            inputs = sorted(known[i].id for i in picks)
            for i in picks:
                need(known[i])
            out = BlockMeta(f"o{j}_{t}", 1, f"o{j}", t)
            need(out)
            dag.add_task(TaskSpec(f"j{j}.t{t}", tuple(inputs), out.id,
                                  job=f"j{j}"))
            new_outputs.append(out)
        known.extend(new_outputs)
        jobs.append(dag)
    return jobs


multi_event_strategy = st.lists(
    st.tuples(st.sampled_from(["submit", "insert", "evict", "load",
                               "task_done"]),
              st.integers(0, 30)),
    min_size=0, max_size=40)


@settings(max_examples=100, deadline=None)
@given(jobs=st.composite(lambda draw: random_jobs(draw))(),
       events=multi_event_strategy)
def test_coordination_replicas_match_oracle(jobs, events):
    """Under multi-job arrival interleaved with evictions and reloads,
    every worker replica (and the master's incremental state) driven only
    by bus messages must agree with a centrally-fed from-scratch oracle,
    and a peer group triggers at most ONE eviction broadcast per
    complete->incomplete transition (§III-C) — here checked in the exact
    form: #broadcasts == #evictions that broke a complete group."""
    master, workers, bus = build_cluster(n_workers=3)
    truth = JobDAG()                       # test-side composed ground truth
    pending_jobs = list(jobs)
    # submit the first job up front so events have something to act on
    first = pending_jobs.pop(0)
    for job in [first]:
        for blk in job.blocks.values():
            if blk.id not in truth.blocks:
                truth.add_block(blk)
        for t in job.tasks.values():
            truth.add_task(t)
    master.submit_job(first)

    in_mem, mat, done = set(), set(), set()
    transitions = 0          # complete -> incomplete flips (ground truth)

    def ground_truth() -> DagState:
        return DagState(truth, materialized=set(mat), cached=set(in_mem),
                        done_tasks=set(done))

    for kind, idx in events:
        if kind == "submit":
            if pending_jobs:
                job = pending_jobs.pop(0)
                for blk in job.blocks.values():
                    if blk.id not in truth.blocks:
                        truth.add_block(blk)
                for t in job.tasks.values():
                    truth.add_task(t)
                master.submit_job(job)
            continue
        blocks = sorted(truth.blocks)
        b = blocks[idx % len(blocks)]
        if kind in ("insert", "load"):
            # "load" after an eviction is the reload that makes groups
            # complete again (re-arming the broadcast protocol)
            if b not in in_mem:
                in_mem.add(b)
                mat.add(b)
                if b in truth.producer:
                    done.add(truth.producer[b])
                # the worker that materialized it reports over the legacy
                # status channel; the master relays to every replica
                workers[0].report_status("materialized", b)
        elif kind == "evict":
            if b in in_mem:
                gt = ground_truth()
                if any(gt.task_live(t) and gt.group_complete(t)
                       for t in truth.consumers.get(b, [])):
                    transitions += 1
                in_mem.discard(b)
                # origin worker applies locally, then runs the full
                # protocol (LERC report if a complete group broke, legacy
                # status either way)
                workers[0].local_eviction(b)
        elif kind == "task_done":
            tasks = sorted(truth.tasks)
            if tasks:
                t = tasks[idx % len(tasks)]
                done.add(t)
                master.status_update("task_done", t)

    oracle = ground_truth()
    for st_ in [master.state] + [w.state for w in workers]:
        assert st_.cached == oracle.cached
        assert st_.materialized == oracle.materialized
        assert st_.done_tasks == oracle.done_tasks
        for b in truth.blocks:
            assert st_.ref_count.get(b, 0) == oracle.ref_count.get(b, 0)
            assert st_.eff_ref_count.get(b, 0) == \
                oracle.eff_ref_count.get(b, 0)
    # protocol overhead: exactly one report+broadcast per flip
    assert bus.stats.eviction_reports == transitions
    assert bus.stats.eviction_broadcasts == transitions

"""Property-based tests (hypothesis) for the system's invariants."""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (BlockMeta, CacheManager, DagState, JobDAG, TaskSpec,
                        build_cluster, make_policy)


# ---------------------------------------------------------------------------
# Random DAG + event-sequence machinery
# ---------------------------------------------------------------------------


def random_dag(draw) -> JobDAG:
    dag = JobDAG()
    n_src = draw(st.integers(3, 8))
    for i in range(n_src):
        dag.add_source("s", i, size=draw(st.integers(1, 3)))
    n_tasks = draw(st.integers(1, 6))
    for t in range(n_tasks):
        k = draw(st.integers(1, min(3, n_src)))
        inputs = tuple(f"s[{i}]" for i in sorted(
            draw(st.sets(st.integers(0, n_src - 1), min_size=k, max_size=k))))
        out = f"o{t}"
        dag.add_block(BlockMeta(out, 1, "o", t))
        dag.add_task(TaskSpec(f"t{t}", inputs, out, job="j"))
    return dag


dag_strategy = st.builds(lambda d: d, st.just(None)).flatmap(
    lambda _: st.composite(lambda draw: random_dag(draw))())

event_strategy = st.lists(
    st.tuples(st.sampled_from(["insert", "evict", "load", "task_done"]),
              st.integers(0, 10)),
    min_size=0, max_size=30)


@settings(max_examples=200, deadline=None)
@given(dag=st.composite(lambda draw: random_dag(draw))(),
       events=event_strategy)
def test_incremental_counts_match_oracle(dag, events):
    """After ANY event sequence, incrementally-maintained ref counts and
    effective ref counts equal a from-scratch rebuild (the paper's
    Definitions computed directly)."""
    state = DagState(dag)
    mgr = CacheManager(capacity=4, policy=make_policy("lerc"), state=state)
    blocks = sorted(dag.blocks)
    tasks = sorted(dag.tasks)
    for kind, idx in events:
        if kind == "insert":
            b = blocks[idx % len(blocks)]
            if b not in mgr.mem and dag.blocks[b].size <= mgr.mem.capacity:
                mgr.insert(b, dag.blocks[b].size)
        elif kind == "evict":
            if mgr.mem.blocks:
                b = sorted(mgr.mem.blocks)[idx % len(mgr.mem.blocks)]
                if b not in mgr.pinned:
                    mgr.evict(b)
        elif kind == "load":
            spilled = sorted(set(mgr.disk.blocks) - set(mgr.mem.blocks))
            if spilled:
                mgr.load_from_disk(spilled[idx % len(spilled)])
        elif kind == "task_done":
            t = tasks[idx % len(tasks)]
            state.on_task_done(t)

    oracle = DagState(dag, materialized=set(state.materialized),
                      cached=set(state.cached),
                      done_tasks=set(state.done_tasks))
    assert state.ref_count == oracle.ref_count
    assert state.eff_ref_count == oracle.eff_ref_count


@settings(max_examples=100, deadline=None)
@given(dag=st.composite(lambda draw: random_dag(draw))())
def test_effective_refs_bounded_by_refs(dag):
    state = DagState(dag)
    mgr = CacheManager(capacity=3, policy=make_policy("lerc"), state=state)
    for b in sorted(dag.blocks)[:5]:
        if dag.blocks[b].size <= 3:
            mgr.insert(b, dag.blocks[b].size)
    for b in dag.blocks:
        assert 0 <= state.eff_ref_count.get(b, 0) <= state.ref_count.get(b, 0)


@settings(max_examples=50, deadline=None)
@given(dag=st.composite(lambda draw: random_dag(draw))(),
       events=event_strategy)
def test_coordination_replicas_match_oracle(dag, events):
    """Worker replicas driven only by bus messages must agree with a
    centrally-maintained oracle, and a peer group triggers at most ONE
    eviction broadcast per complete->incomplete transition (§III-C)."""
    master, workers, bus = build_cluster(n_workers=3)
    master.submit_job(dag)
    oracle = DagState(dag)
    blocks = sorted(dag.blocks)
    in_mem = set()

    transitions = 0          # complete -> incomplete flips (ground truth)
    for kind, idx in events:
        b = blocks[idx % len(blocks)]
        if kind in ("insert", "load"):
            if b not in in_mem:
                in_mem.add(b)
                oracle.on_materialized(b, into_cache=True)
                master.status_update("materialized", b)
        elif kind == "evict":
            if b in in_mem:
                in_mem.discard(b)
                flipped = oracle.on_evicted(b)
                if flipped:
                    transitions += 1
                workers[0].local_eviction(b)

    w = workers[1].state
    assert w.ref_count == oracle.ref_count
    assert w.eff_ref_count == oracle.eff_ref_count
    # protocol overhead: exactly one report+broadcast per flip
    assert bus.stats.eviction_reports == transitions
    assert bus.stats.eviction_broadcasts == transitions

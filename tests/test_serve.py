"""Prefix store (LERC on KV chains) and serve-engine integration tests."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import init_params, model_spec
from repro.serve import PrefixStore, ServeEngine


def _payload():
    return {"kv": np.zeros(4, np.float32)}


def test_chain_all_or_nothing():
    """A resident block below a non-resident ancestor yields no effective
    hit (the paper's property, chain form)."""
    st = PrefixStore(capacity_bytes=1 << 20, policy="lerc", block_tokens=4)
    toks = list(range(12))                      # 3 blocks
    st.insert(toks, [_payload()] * 3, nbytes_per_block=100)
    chain = st._walk(toks)
    st._evict(chain[0])                         # break the root block
    usable = st.lookup(toks)
    assert usable == []                         # nothing usable
    m = st.metrics()
    assert m["hit_ratio"] > 0                   # blocks 2,3 are plain hits
    assert m["effective_hit_ratio"] == 0        # ...but effective = 0


def test_lerc_keeps_requested_chain_under_pressure():
    """Cache full of a requested (hot) chain + an unreferenced (cold) one;
    a new insert forces one eviction. LERC sacrifices the cold chain (zero
    effective references); LRU evicts by recency and breaks the hot one."""
    def build(policy):
        st = PrefixStore(capacity_bytes=400, policy=policy, block_tokens=4)
        hot = list(range(8))                    # 2 blocks, queued requests
        cold = list(range(100, 108))            # 2 blocks, no requests
        st.insert(hot, [_payload()] * 2, nbytes_per_block=100)
        for _ in range(3):
            st.register_request(hot + [1, 2, 3, 4])
        st.insert(cold, [_payload()] * 2, nbytes_per_block=100)
        # cold touched last -> under LRU the hot chain is the LRU victim
        st.insert(list(range(200, 204)), [_payload()],
                  nbytes_per_block=100)         # forces one eviction
        return st, hot

    st, hot = build("lerc")
    assert len(st.lookup(hot)) == 2, "LERC must keep the requested chain"
    st, hot = build("lru")
    assert len(st.lookup(hot)) < 2, "LRU breaks the hot chain (recency)"


def test_lru_vs_lerc_effective_ratio():
    rng = np.random.default_rng(0)
    families = [list(rng.integers(0, 1000, 16)) for _ in range(4)]
    out = {}
    for policy in ("lru", "lrc", "lerc"):
        st = PrefixStore(capacity_bytes=900, policy=policy, block_tokens=4)
        # register a queue that reuses family prefixes
        rids = []
        reqs = []
        for i in range(12):
            fam = families[i % 4]
            req = fam + list(rng.integers(0, 1000, 4))
            reqs.append(req)
            rids.append(st.register_request(req))
        for rid, req in zip(rids, reqs):
            st.lookup(req)
            st.insert(req, [_payload()] * (len(req) // 4),
                      nbytes_per_block=60)
            st.complete_request(rid)
        out[policy] = st.metrics()["effective_hit_ratio"]
    assert out["lerc"] >= out["lru"]


def test_engine_prefix_reuse_and_determinism():
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab, 24))

    eng = ServeEngine(cfg, params, max_slots=2, max_seq=64,
                      store=PrefixStore(1 << 20, "lerc", block_tokens=8))
    r1 = eng.submit(shared + [1, 2, 3], max_new=4)
    eng.run()
    r2 = eng.submit(shared + [4, 5, 6], max_new=4)
    eng.run()
    assert r1.prefill_skipped == 0
    assert r2.prefill_skipped >= 16             # shared prefix reused
    m = eng.metrics()
    assert m["prefill_saved_frac"] > 0

    # identical prompt must generate identical tokens (cold vs warm)
    e2 = ServeEngine(cfg, params, max_slots=1, max_seq=64)
    a = e2.submit(shared[:16], max_new=5)
    e2.run()
    b = e2.submit(shared[:16], max_new=5)
    e2.run()
    assert a.generated == b.generated


def test_engine_continuous_batching_isolation():
    """Interleaved requests in different slots must not contaminate each
    other: same prompt alone vs alongside another request."""
    cfg = configs.get("qwen2_7b", smoke=True)
    params = init_params(jax.random.key(0), model_spec(cfg),
                         dtype=cfg.dtype)
    rng = np.random.default_rng(1)
    p1 = list(rng.integers(0, cfg.vocab, 10))
    p2 = list(rng.integers(0, cfg.vocab, 7))

    solo = ServeEngine(cfg, params, max_slots=1, max_seq=64)
    rs = solo.submit(p1, max_new=4)
    solo.run()

    duo = ServeEngine(cfg, params, max_slots=2, max_seq=64)
    ra = duo.submit(p1, max_new=4)
    rb = duo.submit(p2, max_new=4)
    duo.run()
    assert ra.generated == rs.generated

"""Oracle equivalence: the incremental PrefixStore vs the retained
brute-force reference, across randomized request/evict traces.

The refactored serve path maintains chain reference counts incrementally
(DagState + EvictionIndex); ``ReferencePrefixStore`` recomputes them from
scratch per victim (the seed algorithm). Both must produce identical ERC
values, identical eviction order, identical lookups, and identical
metrics — for every policy the reference covers.
"""
import random

import pytest

from repro.serve import PrefixStore, ReferencePrefixStore

PAYLOAD = {"kv": None}


def random_trace(inc, ref, seed, n_ops=300, vocab=60, bt=4):
    """Drive both stores through one randomized trace, asserting
    equivalence after every operation."""
    rng = random.Random(seed)
    families = [[rng.randrange(vocab) for _ in range(12)] for _ in range(5)]
    live = []

    def toks():
        fam = rng.choice(families)
        t = fam[:rng.randrange(bt, len(fam) + 1)]
        t += [rng.randrange(vocab) for _ in range(rng.randrange(0, bt + 1))]
        return t

    for op in range(n_ops):
        r = rng.random()
        if r < 0.3:
            t = toks()
            rid = inc.register_request(t)
            assert rid == ref.register_request(t)
            live.append((rid, t))
        elif r < 0.5 and live:
            rid, _ = live.pop(rng.randrange(len(live)))
            inc.complete_request(rid)
            ref.complete_request(rid)
        elif r < 0.75:
            t = toks()
            a = inc.lookup(t)
            b = ref.lookup(t)
            assert [n.uid for n in a] == [n.uid for n in b]
        else:
            t = toks()
            n = len(t) // bt
            inc.insert(t, [PAYLOAD] * n, nbytes_per_block=50)
            ref.insert(t, [PAYLOAD] * n, nbytes_per_block=50)
        assert inc.eviction_log == ref.eviction_log, \
            f"eviction order diverged at op {op}"
    assert inc.metrics() == ref.metrics()


@pytest.mark.parametrize("policy", ["lru", "lrc", "lerc"])
@pytest.mark.parametrize("seed", range(5))
def test_eviction_order_matches_bruteforce(policy, seed):
    inc = PrefixStore(capacity_bytes=450, policy=policy, block_tokens=4)
    ref = ReferencePrefixStore(capacity_bytes=450, policy=policy,
                               block_tokens=4)
    random_trace(inc, ref, seed)
    assert inc.evictions > 0, "trace produced no eviction pressure"


@pytest.mark.parametrize("seed", range(5))
def test_erc_values_match_bruteforce(seed):
    """The incremental counters must equal the from-scratch recomputation
    (rc = prefixes at-or-below, erc = those fully resident) AND the
    DagState's own rebuild oracle."""
    inc = PrefixStore(capacity_bytes=450, policy="lerc", block_tokens=4)
    ref = ReferencePrefixStore(capacity_bytes=450, policy="lerc",
                               block_tokens=4)
    random_trace(inc, ref, seed + 100)
    rc, erc = ref._ref_counts()
    for bid in inc._nodes:
        assert inc.state.ref_count.get(bid, 0) == rc.get(bid, 0)
        assert inc.state.eff_ref_count.get(bid, 0) == erc.get(bid, 0)
    # cross-check against the core substrate's from-scratch rebuild
    from repro.core import DagState
    oracle = DagState(inc.dag, materialized=set(inc.state.materialized),
                      cached=set(inc.state.cached),
                      done_tasks=set(inc.state.done_tasks))
    # the incremental dicts are lazy (no entry until first reference), the
    # rebuild oracle is dense — compare values, not dict shapes
    for bid in inc.dag.blocks:
        assert inc.state.ref_count.get(bid, 0) == oracle.ref_count[bid]
        assert inc.state.eff_ref_count.get(bid, 0) == \
            oracle.eff_ref_count[bid]


def test_depth_weighting_prefers_leaves():
    """On a single pending chain, rc/erc are non-increasing with depth, so
    LERC evicts leaves before ancestors (never breaks another chain)."""
    st = PrefixStore(capacity_bytes=10_000, policy="lerc", block_tokens=1)
    toks = list(range(6))
    st.insert(toks, [PAYLOAD] * 6, nbytes_per_block=1)
    st.register_request(toks)
    chain = st._walk(toks)
    rcs = [st.state.ref_count[n.block_id] for n in chain]
    ercs = [st.state.eff_ref_count[n.block_id] for n in chain]
    assert rcs == sorted(rcs, reverse=True)
    assert ercs == sorted(ercs, reverse=True)
    assert rcs[0] == 6 and rcs[-1] == 1        # depth-weighted
    # fully-resident chain: every prefix is complete
    assert ercs == rcs


@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu", "lerc"])
def test_unreferenced_chain_evicts_leaf_first(policy):
    """A resident chain with NO pending references must still be evicted
    leaf-first — evicting the root would orphan every resident descendant
    (their prefixes break, usable length drops to 0)."""
    st = PrefixStore(capacity_bytes=6, policy=policy, block_tokens=1)
    toks = list(range(6))
    st.insert(toks, [PAYLOAD] * 6, nbytes_per_block=1)
    st.insert([100], [PAYLOAD], nbytes_per_block=1)   # forces one eviction
    chain = st._walk(toks)
    assert st.eviction_log == [chain[-1].block_id], \
        f"{policy} must evict the leaf, got {st.eviction_log}"
    assert len(st.lookup(toks)) == 5                  # prefix intact


def test_completed_requests_are_garbage_collected():
    """complete_request retires the adapter tasks from the shared DAG —
    the substrate's footprint tracks pending work, not history."""
    st = PrefixStore(capacity_bytes=10_000, policy="lerc", block_tokens=2)
    n_tasks0 = len(st.dag.tasks)
    rids = [st.register_request(list(range(i, i + 8))) for i in range(10)]
    assert len(st.dag.tasks) > n_tasks0
    for rid in rids:
        st.complete_request(rid)
    assert len(st.dag.tasks) == n_tasks0
    assert not st.state.missing
    assert not st.state.done_tasks
    # skeleton GC: nothing was ever resident, so the whole radix tree —
    # nodes, DAG blocks, counter entries — is pruned with the requests
    assert st._nodes == {}
    assert st.root.children == {}
    assert not st.state.ref_count and not st.state.eff_ref_count


def test_skeleton_gc_respects_sharing_and_residency():
    """complete_request prunes exactly the non-resident, reference-free
    tail of a chain: shared prefixes survive while referenced, resident
    blocks survive eviction pressure bookkeeping, and a fully retired
    non-resident tree vanishes."""
    st = PrefixStore(capacity_bytes=10_000, policy="lerc", block_tokens=2)
    r1 = st.register_request(list(range(8)))              # 4 nodes
    r2 = st.register_request(list(range(4)) + [9] * 4)    # shares 2, +2
    assert len(st._nodes) == 6
    st.complete_request(r1)
    # r1's private tail (2 nodes) pruned; the shared prefix is still
    # referenced by r2
    assert len(st._nodes) == 4
    st.complete_request(r2)
    assert st._nodes == {} and st.root.children == {}
    assert not st.dag.blocks and not st.dag.tasks

    # resident chains survive their requests (they may serve future hits)
    rid = st.register_request(list(range(6)))
    st.insert(list(range(6)), [PAYLOAD] * 3, nbytes_per_block=10)
    st.complete_request(rid)
    assert len(st.lookup(list(range(6)))) == 3
    assert len(st._nodes) == 3

"""Unit tests for the LERC core, anchored on the paper's own examples."""
import pytest

from repro.core import (BlockMeta, CacheManager, DagState, JobDAG, TaskSpec,
                        make_policy)


def fig1_dag(with_e=True):
    """Paper Fig. 1: Task 1 coalesces a,b -> x; Task 2 coalesces c,d -> y.
    All blocks unit size. a, b, c in a 3-entry cache; d on disk; block e is
    then inserted, forcing one eviction."""
    dag = JobDAG()
    for name in "abcd":
        dag.add_source(name, 0, size=1)
    if with_e:
        dag.add_source("e", 0, size=1)
    dag.add_block(BlockMeta(id="x", size=2, dataset="x", index=0))
    dag.add_block(BlockMeta(id="y", size=2, dataset="y", index=0))
    dag.add_task(TaskSpec(id="task1", inputs=("a[0]", "b[0]"), output="x", job="j"))
    dag.add_task(TaskSpec(id="task2", inputs=("c[0]", "d[0]"), output="y", job="j"))
    return dag


def setup_fig1(policy_name, **kw):
    dag = fig1_dag()
    state = DagState(dag)
    mgr = CacheManager(capacity=3, policy=make_policy(policy_name, **kw), state=state)
    # a, b, c materialized into cache; d materialized straight to disk
    for b in ("a[0]", "b[0]", "c[0]"):
        mgr.insert(b, 1)
    mgr.disk.put("d[0]", 1)
    state.on_materialized("d[0]", into_cache=False)
    return dag, state, mgr


def test_fig1_reference_counts():
    _, state, _ = setup_fig1("lerc")
    # every source block has exactly one unmaterialized dependent
    for b in ("a[0]", "b[0]", "c[0]", "d[0]"):
        assert state.ref_count[b] == 1
    # a,b effective (task1's materialized inputs all cached); c not (d on disk)
    assert state.eff_ref_count["a[0]"] == 1
    assert state.eff_ref_count["b[0]"] == 1
    assert state.eff_ref_count["c[0]"] == 0
    assert state.eff_ref_count["d[0]"] == 0


def test_fig1_lerc_evicts_c():
    """The paper's headline example: LERC is the only policy that always
    makes the right call (evict c)."""
    _, state, mgr = setup_fig1("lerc")
    victims = mgr.insert("e[0]", 1)
    assert victims == ["c[0]"], f"LERC must evict c, got {victims}"
    assert mgr.in_memory("a[0]") and mgr.in_memory("b[0]")


def test_fig1_lru_evicts_wrong_block():
    """LRU evicts a (oldest) — caching c without d speeds up nothing."""
    _, state, mgr = setup_fig1("lru")
    victims = mgr.insert("e[0]", 1)
    assert victims == ["a[0]"]  # wrong choice: breaks task1's peer group


def test_fig1_lrc_is_ambiguous():
    """LRC sees ref count 1 for a, b and c alike — with LRU tiebreak it
    evicts a (wrong). The paper: wrong with probability 2/3 under random
    ties."""
    _, state, mgr = setup_fig1("lrc", tiebreak="lru")
    victims = mgr.insert("e[0]", 1)
    assert victims == ["a[0]"]


def test_fig1_effective_hit_ratio_after_choices():
    """Def. 1 arithmetic from §III-A: with a,b cached the effective hit
    ratio over the 4 accesses is 50%; evicting a or b drives it to 0."""
    _, state, mgr = setup_fig1("lerc")
    mgr.insert("e[0]", 1)  # evicts c
    mgr.access_task_inputs("task1")   # a, b : both hits, both effective
    mgr.access_task_inputs("task2")   # c, d : both misses
    m = mgr.metrics
    assert m.accesses == 4
    assert m.hits == 2
    assert m.effective_hits == 2
    assert m.effective_hit_ratio == pytest.approx(0.5)


def test_sticky_policy_shared_block_weakness():
    """§III-A: a block shared by two tasks, one of whose groups is broken,
    must NOT be evicted first — sticky does, LERC does not."""
    dag = JobDAG()
    for name, size in (("s", 1), ("p", 1), ("q", 1)):
        dag.add_source(name, 0, size=size)
    from repro.core import BlockMeta
    dag.add_block(BlockMeta("o1", 1, "o1", 0))
    dag.add_block(BlockMeta("o2", 1, "o2", 0))
    # task A reads (s, p): complete; task B reads (s, q): q on disk -> broken
    dag.add_task(TaskSpec(id="tA", inputs=("s[0]", "p[0]"), output="o1", job="j"))
    dag.add_task(TaskSpec(id="tB", inputs=("s[0]", "q[0]"), output="o2", job="j"))
    state = DagState(dag)

    def stage(policy):
        st = DagState(dag)
        mgr = CacheManager(capacity=3, policy=policy, state=st)
        for b in ("s[0]", "p[0]", "q[0]"):
            mgr.insert(b, 1)
        mgr.evict("q[0]")  # q pushed out -> task B's group broken
        return st, mgr

    st, mgr = stage(make_policy("sticky"))
    # sticky ranks s (member of broken group B) as a bottom-class victim
    sticky_keys = {b: mgr.policy.eviction_key(b, st) for b in ("s[0]", "p[0]")}
    assert sticky_keys["s[0]"] < sticky_keys["p[0]"]

    st, mgr = stage(make_policy("lerc"))
    # LERC: s still has effective ref count 1 (task A complete) == p's
    assert st.eff_ref_count["s[0]"] == 1
    assert st.eff_ref_count["p[0]"] == 1


def test_eviction_and_reload_flips_effective_counts():
    dag = fig1_dag()
    state = DagState(dag)
    mgr = CacheManager(capacity=4, policy=make_policy("lerc"), state=state)
    for b in ("a[0]", "b[0]", "c[0]"):
        mgr.insert(b, 1)
    mgr.disk.put("d[0]", 1)
    state.on_materialized("d[0]", into_cache=False)
    # load d back into cache: task2's group becomes complete
    mgr.load_from_disk("d[0]")
    assert state.eff_ref_count["c[0]"] == 1
    assert state.eff_ref_count["d[0]"] == 1
    # evict b: task1's group breaks
    mgr.evict("b[0]")
    assert state.eff_ref_count["a[0]"] == 0
    assert state.eff_ref_count["b[0]"] == 0


def test_task_completion_decrements_counts():
    _, state, mgr = setup_fig1("lerc")
    mgr.access_task_inputs("task1")
    mgr.insert("x", 2)  # task1's output materializes -> task done
    assert state.ref_count["a[0]"] == 0
    assert state.eff_ref_count["a[0]"] == 0
    assert "task1" in state.done_tasks


def test_incremental_matches_rebuild():
    """The incremental counter maintenance must equal the from-scratch
    oracle after a busy event sequence."""
    _, state, mgr = setup_fig1("lerc")
    mgr.load_from_disk("d[0]")     # evicts c (LERC); mem: a, b, d
    mgr.evict("a[0]")              # mem: b, d
    mgr.load_from_disk("c[0]")     # mem: three of {b, c, d}
    mgr.access_task_inputs("task2")
    mgr.insert("y", 2)             # task2's output -> task2 done
    oracle = DagState(state.dag,
                      materialized=set(state.materialized),
                      cached=set(state.cached),
                      done_tasks=set(state.done_tasks))
    assert state.ref_count == oracle.ref_count
    assert state.eff_ref_count == oracle.eff_ref_count
    assert {t: state.missing[t] for t in state.dag.tasks} == \
           {t: oracle.missing[t] for t in oracle.dag.tasks}
